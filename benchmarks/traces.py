"""Workload synthesis beyond well-behaved pseudo-Poisson traces.

Production serving traffic is bursty (arrivals cluster far beyond what a
Poisson process produces), heavy-tailed (a few huge prompts/outputs
dominate the mass), and multi-class (interactive requests with tight
deadlines share the fleet with batch traffic that only cares about
throughput).  This module builds such traces deterministically — same
seed, same trace, byte for byte — as plain inputs to the engine:

- :func:`mmpp_process` — a 2-state Markov-modulated Poisson process
  (the standard burstiness model: a "calm" and a "burst" rate with
  exponential dwell times).  Returned as an ``arrival_process`` callable
  for :func:`repro.engine.synthetic_requests` or :func:`two_class_trace`.
- :func:`diurnal_process` — a sinusoid-modulated Poisson process (the
  day/night load curve, shrunk to bench time scales) built on the same
  exact boundary-redraw discretization.
- :func:`heavy_tailed_lengths` — bounded-Pareto integer lengths.
- :func:`two_class_trace` — the whole package: MMPP arrivals,
  heavy-tailed prompt/output lengths, and per-class SLO deadlines on an
  interactive/batch split, returning ``EngineRequest`` records.
- :func:`index_of_dispersion` — the burstiness statistic the tests and
  the chaos gate assert on (Poisson counts have IoD ~= 1; MMPP > 1).
"""
from __future__ import annotations

import random
from typing import Callable, List, Optional, Sequence, Tuple

from repro.engine import EngineRequest

ArrivalProcess = Callable[[int, float, int], List[float]]


def poisson_process() -> ArrivalProcess:
    """The engine's default pseudo-Poisson arrivals, in ``arrival_process``
    form (``expovariate`` draws from ``random.Random(seed)`` — the same
    generator discipline ``core.batching.poisson_arrivals`` uses)."""
    def proc(n: int, rate_per_s: float, seed: int) -> List[float]:
        rng = random.Random(seed)
        t, out = 0.0, []
        for _ in range(n):
            t += rng.expovariate(rate_per_s)
            out.append(t)
        return out
    return proc


def mmpp_process(modulation: Tuple[float, float] = (0.25, 4.0),
                 dwell_s: Tuple[float, float] = (0.5, 0.125)
                 ) -> ArrivalProcess:
    """2-state MMPP: state ``k`` emits Poisson arrivals at
    ``rate_per_s * modulation[k]`` and dwells an exponential time of mean
    ``dwell_s[k]`` before switching.  Because exponential inter-arrivals
    are memoryless, discarding the draw that crosses a state boundary
    and redrawing at the boundary's rate is the *exact* process, not an
    approximation.  The defaults give calm traffic punctuated by 16x
    bursts — arrival counts are overdispersed
    (:func:`index_of_dispersion` > 1) while the long-run mean rate stays
    near ``rate_per_s``."""
    if len(modulation) != 2 or len(dwell_s) != 2:
        raise ValueError("mmpp_process takes exactly two states")
    if min(modulation) <= 0 or min(dwell_s) <= 0:
        raise ValueError("modulation factors and dwell times must be > 0")

    def proc(n: int, rate_per_s: float, seed: int) -> List[float]:
        rng = random.Random(seed)
        t, state = 0.0, 0
        state_end = rng.expovariate(1.0 / dwell_s[0])
        out: List[float] = []
        while len(out) < n:
            dt = rng.expovariate(rate_per_s * modulation[state])
            if t + dt > state_end:
                t = state_end
                state = 1 - state
                state_end = t + rng.expovariate(1.0 / dwell_s[state])
                continue
            t += dt
            out.append(t)
        return out
    return proc


def diurnal_process(depth: float = 0.8, period_s: float = 1.0,
                    steps_per_period: int = 32,
                    phase: float = 0.0) -> ArrivalProcess:
    """Sinusoid-modulated Poisson arrivals: the diurnal (day/night) load
    curve every datacenter trace shows, shrunk to bench time scales.

    The instantaneous rate is a staircase discretization of
    ``rate_per_s * (1 + depth * sin(2*pi*(t / period_s + phase)))``,
    piecewise-constant over ``steps_per_period`` equal slices of each
    period (evaluated at each slice's midpoint).  Within a slice arrivals
    are exactly Poisson at the slice's rate; a draw that crosses a slice
    boundary is discarded and redrawn at the next slice's rate — the
    same memoryless boundary-redraw :func:`mmpp_process` uses, so the
    discretized process is exact, not approximate.  ``depth`` in [0, 1)
    keeps every slice's rate positive; the long-run mean rate stays near
    ``rate_per_s`` while counts are overdispersed on horizons past a
    fraction of a period (:func:`index_of_dispersion` > 1) — slower,
    smoother burstiness than MMPP's state flips."""
    import math
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")
    if period_s <= 0:
        raise ValueError(f"period_s must be > 0, got {period_s}")
    if steps_per_period < 2:
        raise ValueError(f"steps_per_period must be >= 2, "
                         f"got {steps_per_period}")

    def proc(n: int, rate_per_s: float, seed: int) -> List[float]:
        rng = random.Random(seed)
        slice_s = period_s / steps_per_period

        def slice_rate(k: int) -> float:
            frac = (k + 0.5) / steps_per_period + phase
            return rate_per_s * (1.0 + depth * math.sin(2 * math.pi * frac))

        t, k = 0.0, 0
        out: List[float] = []
        while len(out) < n:
            dt = rng.expovariate(slice_rate(k))
            if t + dt > (k + 1) * slice_s:
                t = (k + 1) * slice_s
                k += 1
                continue
            t += dt
            out.append(t)
        return out
    return proc


def heavy_tailed_lengths(n: int, *, lo: int, hi: int,
                         alpha: float = 1.6, seed: int = 0) -> List[int]:
    """Bounded-Pareto integer lengths in ``[lo, hi]`` via the inverse
    CDF: most draws sit near ``lo``, a heavy tail reaches ``hi`` — the
    shape real prompt/output length distributions have.  Smaller
    ``alpha`` = heavier tail."""
    if not 1 <= lo <= hi:
        raise ValueError(f"need 1 <= lo <= hi, got lo={lo}, hi={hi}")
    if alpha <= 0:
        raise ValueError(f"alpha must be > 0, got {alpha}")
    rng = random.Random(seed * 7919 + 17)
    la, ha = lo ** -alpha, hi ** -alpha
    out = []
    for _ in range(n):
        u = rng.random()
        x = (la - u * (la - ha)) ** (-1.0 / alpha)
        out.append(min(hi, max(lo, int(round(x)))))
    return out


def index_of_dispersion(times: Sequence[float], *,
                        window_s: float = 0.25) -> float:
    """Variance-to-mean ratio of arrival counts in fixed windows: ~1 for
    Poisson, > 1 for bursty (overdispersed) traffic.  The statistic the
    trace tests and the chaos gate pin burstiness with."""
    if not times:
        return 0.0
    horizon = times[-1] + 1e-9
    nwin = max(1, int(horizon / window_s))
    counts = [0] * nwin
    for t in times:
        counts[min(nwin - 1, int(t / window_s))] += 1
    mean = sum(counts) / nwin
    if mean == 0:
        return 0.0
    var = sum((c - mean) ** 2 for c in counts) / nwin
    return var / mean


def two_class_trace(n: int, *, rate_per_s: float, vocab: int,
                    seed: int = 0,
                    interactive_frac: float = 0.7,
                    interactive_deadline_s: float = 0.25,
                    batch_deadline_s: float = 8.0,
                    prompt_len: Tuple[int, int] = (2, 12),
                    max_new_tokens: Tuple[int, int] = (2, 10),
                    alpha: float = 1.6,
                    arrival: Optional[ArrivalProcess] = None,
                    models: Optional[Sequence[Tuple[str, int]]] = None
                    ) -> List[EngineRequest]:
    """A bursty two-class trace: MMPP arrivals (by default), bounded-
    Pareto prompt/output lengths, and per-class SLO deadlines.  Request
    ``rid`` is interactive iff ``(rid * 2654435761) % 1000 <
    interactive_frac * 1000`` — a deterministic hash split, so the class
    mix is stable under any ``n``.  Prompts are rid-derived exactly like
    ``synthetic_requests`` (two runs see identical token streams).

    ``models`` makes it a multi-model trace for a multiplexed engine: a
    sequence of ``(tag, vocab)`` pairs, request ``rid`` round-robins to
    ``models[rid % len(models)]``, gets that lane's tag stamped on
    ``EngineRequest.model``, and draws its prompt tokens inside that
    lane's OWN vocab (the ``vocab`` argument is ignored for tagged
    requests).  Arrivals, lengths, and the class split are unchanged, so
    the trace with ``models=None`` stays byte-identical to before."""
    if not 0.0 <= interactive_frac <= 1.0:
        raise ValueError(f"interactive_frac must be in [0, 1], "
                         f"got {interactive_frac}")
    times = (arrival or mmpp_process())(n, rate_per_s, seed)
    plens = heavy_tailed_lengths(n, lo=prompt_len[0], hi=prompt_len[1],
                                 alpha=alpha, seed=seed)
    glens = heavy_tailed_lengths(n, lo=max_new_tokens[0],
                                 hi=max_new_tokens[1], alpha=alpha,
                                 seed=seed + 1)
    reqs = []
    for rid, t in enumerate(times):
        interactive = (rid * 2654435761) % 1000 < interactive_frac * 1000
        cls = "interactive" if interactive else "batch"
        ddl = interactive_deadline_s if interactive else batch_deadline_s
        tag, v = (None, vocab) if models is None \
            else models[rid % len(models)]
        prompt = tuple(1 + (rid * 7 + 3 * j) % (v - 1)
                       for j in range(plens[rid]))
        reqs.append(EngineRequest(
            rid=rid, prompt=prompt, max_new_tokens=glens[rid],
            arrival_s=t, deadline_s=t + ddl, priority=cls, model=tag))
    return reqs
