"""Kernel micro-benchmarks: the quantized matmul path vs the fp path.

On CPU these time the oracle implementations (the Pallas kernels target
TPU; interpret mode is a correctness tool, not a timing tool), so the
derived column also reports the *bytes* ratio — the quantity the paper's
technique actually improves and the one the roofline uses.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.quant import quantize_weight
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def qmatmul_bench():
    rows = []
    key = jax.random.PRNGKey(0)
    for m, k, n in ((256, 2048, 2048), (32, 4096, 4096)):
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (m, k), jnp.float32)
        w_fp = jax.random.normal(k2, (k, n), jnp.float32)
        w_q = quantize_weight(w_fp)

        fp = jax.jit(lambda a, b: a @ b)
        q16 = jax.jit(lambda a, wq: ops.qmatmul(a, wq,
                                                out_dtype=jnp.float32))
        t_fp = _time(fp, x, w_fp)
        t_q = _time(q16, x, w_q)
        fp_bytes = w_fp.size * 4
        q_bytes = w_q.values.size + w_q.scale.size * 4
        rows.append((f"kernel/qmatmul_{m}x{k}x{n}", t_q * 1e6,
                     f"fp_us={t_fp*1e6:.0f} weight_bytes_ratio="
                     f"{fp_bytes/q_bytes:.2f} (target 4x vs fp32, 2x vs "
                     f"bf16)"))
    return rows


ALL = [qmatmul_bench]
