# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    from benchmarks import kernel_bench, paper_tables, roofline_report
    print("name,us_per_call,derived")
    failures = 0
    suites = list(paper_tables.ALL) + list(kernel_bench.ALL) + \
        [roofline_report.rows]
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # keep the suite running; report at the end
            failures += 1
            print(f"{fn.__name__},0.00,ERROR {type(e).__name__}: {e}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
