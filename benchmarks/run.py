# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# --smoke: fast post-refactor sanity gate (cost pipeline + kernel bench).
# --bench-out PATH: write the serving perf trajectory (tokens/s,
#   service-time curve, autotuned tiles, kernel bench) as schema'd JSON —
#   the BENCH_serving.json every future perf PR has to beat.
import argparse
import json
import sys


BENCH_SCHEMA_VERSION = 1


def _kernel_bench_rows():
    """kernel_bench CSV rows, also printed by --smoke (perf guard)."""
    from benchmarks import kernel_bench
    rows = []
    for fn in kernel_bench.ALL:
        rows.extend(fn())
    return rows


def write_bench_json(path: str, kernel_rows=None) -> None:
    """Emit the serving benchmark JSON (schema asserted by tests)."""
    import jax

    from benchmarks import serving_bench

    rows = serving_bench.serving_rows()
    if kernel_rows is None:
        kernel_rows = _kernel_bench_rows()
    for name, us, derived in kernel_rows:
        rows.append({"kind": "kernel_bench", "name": name,
                     "us_per_call": us, "derived": derived})
    doc = {"schema_version": BENCH_SCHEMA_VERSION,
           "backend": jax.default_backend(),
           "rows": rows}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    kinds = sorted({r["kind"] for r in rows})
    print(f"[bench] wrote {len(rows)} rows ({', '.join(kinds)}) -> {path}")


def smoke(kernel_rows=None) -> int:
    """Fast post-refactor sanity gate: compile ONE reduced config, derive
    its roofline cell through `core.roofline` (structural hlo_cost under the
    hood), render it through the roofline report, assert nonzero
    flops/bytes, and print the kernel micro-bench rows (timed here unless
    the caller already ran them)."""
    import json
    import os
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.core import roofline as RL

    batch, d, layers = 16, 128, 4       # reduced scan-over-layers config

    def stack(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        return jax.lax.scan(body, x, w)[0]

    compiled = jax.jit(stack).lower(
        jax.ShapeDtypeStruct((batch, d), jnp.float32),
        jax.ShapeDtypeStruct((layers, d, d), jnp.float32)).compile()
    terms = RL.from_compiled("smoke/scan_stack/single", compiled, chips=1,
                             model_flops=2 * batch * d * d * layers)

    with tempfile.TemporaryDirectory() as tmp:
        cell = terms.to_dict()
        cell.update({"status": "ok", "arch": "smoke", "shape": "scan_stack",
                     "mesh": "single"})
        with open(os.path.join(tmp, "smoke.json"), "w") as f:
            json.dump(cell, f)
        from benchmarks import roofline_report
        print(roofline_report.markdown_table(results_dir=tmp))
        print()
        print("Per-op breakdown (from hlo_cost CostTotals.by_op):")
        print(roofline_report.breakdown_table(results_dir=tmp))

    assert terms.hlo_flops > 0, "smoke: zero FLOPs from hlo_cost"
    assert terms.hlo_bytes > 0, "smoke: zero bytes from hlo_cost"
    assert terms.hlo_flops == 2 * batch * d * d * layers, \
        f"smoke: flops {terms.hlo_flops} != model {2 * batch * d * d * layers}"
    assert terms.by_op and terms.by_op.get("dot", {}).get("flops", 0) > 0, \
        "smoke: per-op breakdown missing dot flops"

    print("\nKernel micro-bench (name,us_per_call,derived):")
    for name, us, derived in (kernel_rows if kernel_rows is not None
                              else _kernel_bench_rows()):
        print(f"{name},{us:.2f},{derived}")

    # continuous-batching engine: short CPU run, outputs must match the
    # sequential per-token reference bit-for-bit; append-path kernel
    # parity under the Pallas interpreter rides along (offline-safe)
    from benchmarks import serving_bench
    eng = serving_bench.engine_smoke()
    print(f"\n[engine] smoke: {eng['requests']} requests in "
          f"{eng['ticks']} ticks, occupancy {eng['mean_occupancy']:.1%}, "
          f"{eng['admissions_while_busy']} mid-flight admissions, "
          f"ttft {eng['mean_ttft_s']*1e3:.2f} -> "
          f"{eng['chunked_mean_ttft_s']*1e3:.2f} ms chunked; "
          f"sequential-reference parity (dense + ssm + encdec primed "
          f"cross-K/V, per-token + chunked prefill) + append-path "
          f"kernel parity OK; paged KV: {eng['paged_requests']}-request "
          f"shared-prefix trace parity OK "
          f"({eng['paged_shared_block_hits']} shared block hits, "
          f"{eng['paged_prefill_tokens_skipped']} prefill tokens "
          f"skipped), blocks-limited admission served "
          f"{eng['paged_limited_peak_occupancy']} concurrent requests "
          f"from a 4-row block budget, block-gather kernel parity OK")

    # chaos gate: a bursty two-class trace with seeded faults and forced
    # preemptions must complete with zero uncaught exceptions, no leaked
    # KV blocks, and bit-for-bit parity on every non-failed output (plus
    # a no-fault control arm matching the sequential reference)
    chaos = serving_bench.chaos_smoke()
    print(f"[chaos] smoke: {chaos['requests']} requests survived "
          f"{chaos['faults_fired']} injected faults "
          f"({chaos['dispatch_retries']} dispatch retries, "
          f"{chaos['nonfinite_samples']} non-finite samples caught, "
          f"{chaos['torn_rows_repaired']} torn block-table rows "
          f"repaired) and {chaos['preempted']} preemptions with "
          f"{chaos['failed']} typed failures, {chaos['leaked_blocks']} "
          f"leaked blocks, exact-resume parity on every non-failed "
          f"output; goodput {chaos['goodput_tokens_per_s']:.0f} tok/s "
          f"at {chaos['slo_attainment']:.1%} SLO attainment; no-fault "
          f"control arm bit-for-bit OK")

    # speculative gate: full-depth self-draft under chaos, a garbage
    # draft, and the non-spec control must all stay bit-for-bit the
    # sequential reference (acceptance is exact, rejected KV is dead)
    spec = serving_bench.spec_smoke()
    print(f"[spec] smoke: {spec['requests']} requests through "
          f"draft-and-verify — full-depth self-draft committed "
          f"{spec['chaos_accepted_per_dispatch']:.2f} tokens/dispatch "
          f"under {spec['preempted']} preemptions and "
          f"{spec['faults_fired']} injected faults "
          f"({spec['leaked_blocks']} leaked blocks), garbage draft "
          f"held exact outputs at "
          f"{spec['garbage_accepted_per_dispatch']:.2f} tokens/dispatch, "
          f"non-spec control at exactly 1.00; bit-for-bit parity OK")

    # multi-model gate: two families multiplexed on one engine under
    # chaos (preemption + seeded cross-lane faults + tight per-lane
    # block pools) must hold per-model bit-for-bit parity, drain both
    # block pools clean, and consolidate occupancy past either
    # dedicated engine at the same offered rates
    mux = serving_bench.multiplex_smoke()
    print(f"[multiplex] smoke: {mux['requests']} two-model requests "
          f"survived {mux['faults_fired']} cross-lane faults and "
          f"{mux['preempted']} preemptions with {mux['failed']} typed "
          f"failures, {mux['leaked_blocks']} leaked blocks; per-model "
          f"sequential-reference parity OK; model-fingerprinted prefix "
          f"keys OK; multiplexed occupancy "
          f"{mux['multiplexed_occupancy']:.1%} beats both dedicated "
          f"engines (per-model occupancy "
          f"{ {t: round(v, 3) for t, v in mux['model_mean_occupancy'].items()} })")

    # fleet gate: 2 replicas x 2 model lanes behind the replica router
    # on a bursty trace with preemption — routed outputs bit-for-bit
    # each lane's sequential reference, zero leaked blocks fleet-wide,
    # both replicas loaded
    rt = serving_bench.router_smoke()
    print(f"[router] smoke: {rt['requests']} two-model requests across "
          f"{rt['replicas']} replicas "
          f"({rt['replica_requests']}, occupancy "
          f"{rt['replica_occupancy']}), {rt['preempted']} preemptions, "
          f"{rt['leaked_blocks']} leaked blocks; per-model "
          f"sequential-reference parity OK; goodput "
          f"{rt['goodput_tokens_per_s']:.0f} tok/s")

    # tensor-parallel gate: sharded executor vs single-device engine,
    # bit-for-bit on the same trace (tp=1 conformance always; the
    # multi-device pair needs a forced host mesh and skips gracefully)
    sh = serving_bench.sharded_smoke()
    if "skipped" in sh:
        print(f"[sharded] smoke: skipped ({sh['skipped']})")
    elif sh["multi_device"]:
        print(f"[sharded] smoke: tp={sh['tp']} across {sh['devices']} "
              f"devices, {sh['requests']} requests bit-identical to the "
              f"single-device engine; parity OK")
    else:
        print(f"[sharded] smoke: tp=1 conformance parity OK "
              f"({sh['requests']} requests); multi-device pair skipped "
              f"({sh['skipped_multi']})")

    print("\nsmoke OK: flops/bytes nonzero, scan trip count exact")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="compile one reduced config and sanity-check the "
                         "roofline/cost pipeline end to end")
    ap.add_argument("--bench-out", metavar="PATH", default=None,
                    help="write serving perf rows (tokens/s, service-time "
                         "curve, chosen tiles, kernel bench) as JSON")
    args = ap.parse_args()
    if args.smoke:
        kernel_rows = _kernel_bench_rows() if args.bench_out else None
        rc = smoke(kernel_rows)
        if args.bench_out:
            write_bench_json(args.bench_out, kernel_rows)
        sys.exit(rc)
    if args.bench_out:
        write_bench_json(args.bench_out)
        sys.exit(0)

    from benchmarks import kernel_bench, paper_tables, roofline_report
    from benchmarks import serving_bench
    print("name,us_per_call,derived")
    failures = 0
    suites = list(paper_tables.ALL) + list(kernel_bench.ALL) + \
        list(serving_bench.ALL) + [roofline_report.rows]
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # keep the suite running; report at the end
            failures += 1
            print(f"{fn.__name__},0.00,ERROR {type(e).__name__}: {e}",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
