"""One benchmark per paper table/figure.  Each prints CSV rows
``name,us_per_call,derived`` where `derived` carries the table's headline
quantity; `us_per_call` is the modeled/measured time where meaningful."""
from __future__ import annotations

import sys

from repro.core import batching as bt
from repro.core import perfmodel as pm


def table1_apps():
    """Table 1: the six-app workload census."""
    rows = []
    for app in pm.PAPER_APPS:
        rows.append((f"table1/{app.name}", 0.0,
                     f"weights={app.weight_bytes/1e6:.1f}M "
                     f"ops_per_byte={app.ops_per_weight_byte:.0f} "
                     f"batch={app.batch} share={app.share:.3f}"))
    return rows


def table2_platforms():
    """Table 2: platform peaks (TPU modeled; CPU/GPU constants from the
    paper since we cannot measure 2015 hardware)."""
    tpu = pm.TPU_V1
    rows = [
        ("table2/TPU", 0.0,
         f"peak_tops={tpu.peak_ops/1e12:.1f} mem_gbps={tpu.mem_bw/1e9:.0f} "
         f"onchip_mib=28 tdp_w=75"),
        ("table2/Haswell", 0.0,
         "peak_tops=2.6 mem_gbps=51 onchip_mib=51 tdp_w=145"),
        ("table2/K80", 0.0,
         "peak_tops=2.8 mem_gbps=160 onchip_mib=8 tdp_w=150"),
        ("table2/ratio_TPU_vs_K80_macs", 0.0,
         f"macs_ratio={65536/2496:.1f} (paper: 25x)"),
    ]
    return rows


def table3_counters():
    """Table 3: per-app cycle breakdown + TOPS from the perf model."""
    rows = []
    for app in pm.PAPER_APPS:
        r = pm.simulate(app)
        rows.append((f"table3/{app.name}", r.time_s * 1e6,
                     f"tops={r.tops:.1f} paper_tops={app.paper_tops} "
                     f"active={r.active_frac:.1%} stall={r.stall_frac:.1%} "
                     f"shift={r.shift_frac:.1%} "
                     f"nonmatrix={r.nonmatrix_frac:.1%} ips={r.ips:,.0f}"))
    errs = [abs(pm.simulate(a).tops / a.paper_tops - 1)
            for a in pm.PAPER_APPS]
    rows.append(("table3/mean_abs_err", 0.0,
                 f"{sum(errs)/len(errs):.1%} (paper model: 8%, Table 7)"))
    return rows


def table4_latency():
    """Table 4: batch vs 99th-percentile latency at the 7 ms bound."""
    rows = []
    for model, cap in ((bt.TABLE4_CPU, 64), (bt.TABLE4_GPU, 64),
                       (bt.TABLE4_TPU, 250)):
        b, lat, ips, frac = bt.table4_row(model, 7e-3, max_batch=cap)
        rows.append((f"table4/{model.name}", lat * 1e6,
                     f"batch={b} ips={ips:,.0f} frac_of_max={frac:.0%}"))
    return rows


def table6_relative():
    """Table 6: relative inference performance per die (GM and WM).

    CPU/GPU die performance uses the paper's measured relatives (they are
    2015 hardware); the TPU column comes from OUR perf model normalized the
    same way, so the comparison tests the model, not a copy."""
    paper_cpu_tops = {"MLP0": 12.3 / 41.0, "MLP1": 9.7 / 18.5,
                      "LSTM0": 3.7 / 3.5, "LSTM1": 2.8 / 1.2,
                      "CNN0": 86.0 / 40.3, "CNN1": 14.1 / 71.0}
    rels = []
    rows = []
    for app in pm.PAPER_APPS:
        tpu_tops = pm.simulate(app).tops
        rel = tpu_tops / paper_cpu_tops[app.name]
        rels.append((rel, app.share))
        rows.append((f"table6/{app.name}", 0.0,
                     f"tpu_vs_cpu={rel:.1f} (paper: "
                     f"{ {'MLP0':41.0,'MLP1':18.5,'LSTM0':3.5,'LSTM1':1.2,'CNN0':40.3,'CNN1':71.0}[app.name] })"))
    import math
    gm = math.exp(sum(math.log(max(r, 1e-9)) for r, _ in rels) / len(rels))
    wm = sum(r * w for r, w in rels) / sum(w for _, w in rels)
    rows.append(("table6/geomean", 0.0, f"gm={gm:.1f} (paper: 14.5)"))
    rows.append(("table6/weighted", 0.0, f"wm={wm:.1f} (paper: 29.2)"))
    return rows


def table8_buffer():
    """Table 8: modeled Unified Buffer occupancy per app."""
    paper = {"MLP0": 11.0, "MLP1": 2.3, "LSTM0": 4.8, "LSTM1": 4.5,
             "CNN0": 1.5, "CNN1": 13.9}
    rows = []
    for app in pm.PAPER_APPS:
        mib = pm.unified_buffer_mib(app)
        rows.append((f"table8/{app.name}", 0.0,
                     f"model_mib={mib:.1f} paper_mib={paper[app.name]} "
                     f"fits_24mib={mib < 24}"))
    return rows


def fig5_roofline():
    """Fig 5: TPU roofline placement of the six apps."""
    rows = []
    for app in pm.PAPER_APPS:
        i, attain, ach = pm.roofline_point(app)
        rows.append((f"fig5/{app.name}", 0.0,
                     f"intensity={i:.0f} attainable_tops={attain:.1f} "
                     f"achieved_tops={ach:.1f}"))
    rows.append(("fig5/ridge", 0.0,
                 f"ops_per_byte={pm.TPU_V1.ridge_ops_per_byte:.0f} "
                 f"(paper: ~1350)"))
    return rows


def fig11_sensitivity():
    """Fig 11: design-knob sweep + TPU' evaluation."""
    rows = []
    sweep = pm.fig11_sweep()
    for knob, pts in sweep.items():
        vals = " ".join(f"{s}x:{p:.2f}" for s, p in pts)
        rows.append((f"fig11/{knob}", 0.0, vals))
    g = pm.tpu_prime_gains()
    rows.append(("fig11/tpu_prime", 0.0,
                 f"gddr5_gm={g['gddr5_gm']:.1f} (paper 2.6) "
                 f"gddr5_wm={g['gddr5_wm']:.1f} (paper 3.9) "
                 f"clock_only_wm={g['clock1.5_wm']:.2f} (paper ~1.0)"))
    return rows


def hlo_cost_breakdown():
    """Where FLOPs/bytes come from: per-op breakdown of a compiled
    scan-over-layers FC stack (MLP0-shaped proxy at reduced dims), from the
    structural HLO cost engine (CostTotals.by_op).

    This is the engine behind every roofline row — the breakdown makes the
    counts auditable instead of one opaque scalar: the dot flops must equal
    2*B*D*D*L exactly, with bytes split across slice/dot/copy traffic."""
    import jax
    import jax.numpy as jnp

    from repro.core import hlo_cost as HC

    batch, d, layers = 32, 256, 5          # MLP0: 5 FC layers, scanned

    def mlp_stack(x, w):
        def body(h, wi):
            return jnp.maximum(h @ wi, 0.0), None
        return jax.lax.scan(body, x, w)[0]

    c = jax.jit(mlp_stack).lower(
        jax.ShapeDtypeStruct((batch, d), jnp.float32),
        jax.ShapeDtypeStruct((layers, d, d), jnp.float32)).compile()
    t = HC.analyze(c.as_text())
    expect = 2 * batch * d * d * layers
    rows = [("hlo_breakdown/total", 0.0,
             f"flops={t.flops:.3e} (exact={t.flops == expect}) "
             f"bytes={t.bytes:.3e} unparsed_whiles={t.unparsed_whiles}")]
    for op, oc in t.breakdown():
        rows.append((f"hlo_breakdown/{op}", 0.0,
                     f"flops={oc.flops:.3e} bytes={oc.bytes:.3e} "
                     f"count={oc.count:.0f}"))
    return rows


ALL = [table1_apps, table2_platforms, table3_counters, table4_latency,
       table6_relative, table8_buffer, fig5_roofline, fig11_sensitivity,
       hlo_cost_breakdown]
