"""Serving-path benchmark rows: tokens/s, service-time curve, chosen tiles.

The perf trajectory every future PR has to beat.  Runs a reduced arch end
to end on whatever backend is present (CPU offline, TPU in production):
post-training int8 quantization, the measured prefill service curve, the
fused multi-token decode loop (jit'd ``lax.scan``, donated int8 KV cache),
and the autotuner's chosen tile configs for the arch's serving matmuls.

Row schema (stable; asserted by tests/test_bench_smoke.py)::

  {"kind": "tokens_per_s",  "arch", "batch", "num_tokens", "tokens_per_s",
   "seconds"}
  {"kind": "service_time",  "arch", "batch", "seconds"}
  {"kind": "chosen_tile",   "arch", "op", "m", "k", "n", "mode",
   "bm", "bn", "bk", "vmem_bytes"}
"""
from __future__ import annotations

import dataclasses
import warnings


def serving_rows(arch: str = "starcoder2-3b", *, quant: str = "w8a16",
                 seq: int = 16, decode_tokens: int = 8,
                 batches=(1, 8), tile_m=(8, 32, 128)):
    """Benchmark one reduced arch; returns a list of schema rows."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.qlinear import FP, W8A16, W8A8
    from repro.core.quant import quantize_tree
    from repro.kernels import autotune as AT
    from repro.launch import serve as SV
    from repro.models import registry as R
    from repro.runtime import steps as ST

    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[quant]
    # int8 KV cache: the serving configuration this PR's decode path is for
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    if mode.enabled:
        params = quantize_tree(params, min_size=2048)

    rows = []
    prefill = jax.jit(ST.make_prefill_step(cfg, mode=mode))
    _, curve = SV.measure_service_curve(
        prefill, params, cfg, batches=batches, seq=seq, iters=2,
        max_batch=max(batches), return_times=True)
    for b, t in sorted(curve.items()):
        rows.append({"kind": "service_time", "arch": cfg.name,
                     "batch": b, "seconds": t})

    with warnings.catch_warnings():
        # CPU backends warn that donated buffers were not usable
        warnings.simplefilter("ignore")
        for b in batches:
            bb, tps, dt = SV.measure_decode_tps(
                cfg, params, mode, b, s_max=max(2 * seq, 64),
                num_tokens=decode_tokens, iters=2)
            rows.append({"kind": "tokens_per_s", "arch": cfg.name,
                         "batch": bb, "num_tokens": decode_tokens,
                         "tokens_per_s": tps, "seconds": dt})

    for r in AT.tune_arch(cfg, m_values=tile_m):
        r = dict(r)
        r["kind"] = "chosen_tile"
        rows.append(r)
    return rows


def rows():
    """CSV-style rows for benchmarks/run.py's default suite."""
    out = []
    for r in serving_rows():
        if r["kind"] == "tokens_per_s":
            out.append((f"serving/decode_tps_b{r['batch']}",
                        r["seconds"] * 1e6,
                        f"tokens_per_s={r['tokens_per_s']:.0f}"))
        elif r["kind"] == "service_time":
            out.append((f"serving/service_b{r['batch']}",
                        r["seconds"] * 1e6, "prefill"))
    return out


ALL = [rows]
