"""Serving-path benchmark rows: tokens/s, service-time curve, chosen tiles.

The perf trajectory every future PR has to beat.  Runs a reduced arch end
to end on whatever backend is present (CPU offline, TPU in production):
post-training int8 quantization, the measured prefill service curve, the
fused multi-token decode loop (jit'd ``lax.scan``, donated int8 KV cache),
and the autotuner's chosen tile configs for the arch's serving matmuls.

Row schema (stable; asserted by tests/test_bench_smoke.py)::

  {"kind": "tokens_per_s",  "arch", "batch", "num_tokens", "tokens_per_s",
   "seconds"}
  {"kind": "service_time",  "arch", "batch", "seconds"}
  {"kind": "chosen_tile",   "arch", "op", "m", "k", "n", "mode",
   "bm", "bn", "bk", "vmem_bytes"}
  {"kind": "engine",        "arch", "family", "rate", "n_requests",
   "num_slots", "p99_s", "tokens_per_s", "mean_occupancy", "ticks",
   "admissions_while_busy", "occupancy_curve", "prefill_chunk",
   "mean_ttft_s", "p99_ttft_s", "block_size", "num_blocks",
   "kv_hbm_bytes", "peak_blocks_used", "mean_block_util",
   "shared_block_hits", "shared_hit_rate", "prefill_tokens_skipped",
   "effective_concurrency", "spec_k", "draft_layers",
   "accepted_per_dispatch", "latency_per_token_s", "model",
   "model_p99_s", "model_mean_ttft_s", "model_goodput_tokens_per_s",
   "model_mean_occupancy"}

The ``engine`` rows are the continuous-batching section: one row per
(family, offered rate) — p99 vs load is the Table 4 story told by the
live engine, now for EVERY registry family (dense, moe, ssm, hybrid,
encdec, vlm — the last two behind per-slot primed cross-K/V, so their
ttft includes the prime dispatch), with the slot-occupancy curve
downsampled inline and the admission-to-first-token columns showing
what chunked prefill buys.  The memory columns (KV-HBM bytes resident,
block utilization, shared-prefix hit rate, effective concurrency) are
live on every row; the non-default values come from the paged-KV rows
(``block_size`` set), where admission is priced in worst-case blocks
and identical prompt prefixes share refcounted blocks.
Timing comes from a measured per-tick cost replayed under the virtual
clock, so the rows are structurally deterministic offline while still
tracking real step cost.
"""
from __future__ import annotations

import dataclasses
import warnings


def serving_rows(arch: str = "starcoder2-3b", *, quant: str = "w8a16",
                 seq: int = 16, decode_tokens: int = 8,
                 batches=(1, 8), tile_m=(8, 32, 128)):
    """Benchmark one reduced arch; returns a list of schema rows."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.qlinear import FP, W8A16, W8A8
    from repro.core.quant import quantize_tree
    from repro.kernels import autotune as AT
    from repro.launch import serve as SV
    from repro.models import registry as R
    from repro.runtime import steps as ST

    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[quant]
    # int8 KV cache: the serving configuration this PR's decode path is for
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    if mode.enabled:
        params = quantize_tree(params, min_size=2048)

    rows = []
    prefill = jax.jit(ST.make_prefill_step(cfg, mode=mode))
    _, curve = SV.measure_service_curve(
        prefill, params, cfg, batches=batches, seq=seq, iters=2,
        max_batch=max(batches), return_times=True)
    for b, t in sorted(curve.items()):
        rows.append({"kind": "service_time", "arch": cfg.name,
                     "batch": b, "seconds": t})

    with warnings.catch_warnings():
        # CPU backends warn that donated buffers were not usable
        warnings.simplefilter("ignore")
        for b in batches:
            bb, tps, dt = SV.measure_decode_tps(
                cfg, params, mode, b, s_max=max(2 * seq, 64),
                num_tokens=decode_tokens, iters=2)
            rows.append({"kind": "tokens_per_s", "arch": cfg.name,
                         "batch": bb, "num_tokens": decode_tokens,
                         "tokens_per_s": tps, "seconds": dt})

    for r in AT.tune_arch(cfg, m_values=tile_m):
        r = dict(r)
        r["kind"] = "chosen_tile"
        rows.append(r)
    rows.extend(engine_rows(arch, quant=quant))
    # EVERY registry family through the same slot engine (the paper's
    # all-NN-families serving argument): compact per-family rows — the
    # encdec/vlm entries decode behind per-slot primed cross-K/V, so
    # their ttft columns include the prime dispatch cost
    for fam_arch in ("qwen2-moe-a2.7b", "mamba2-1.3b", "recurrentgemma-9b",
                     "whisper-medium", "llama-3.2-vision-90b"):
        rows.extend(engine_rows(fam_arch, quant=quant, rates=(400.0,),
                                n_requests=10, num_slots=4, prompt_len=6,
                                gen_tokens=4))
    # the paged-KV engine row: block-table decode with a shared system
    # prompt, so the memory columns show block reuse under load
    rows.extend(engine_rows(arch, quant=quant, rates=(800.0,),
                            n_requests=16, num_slots=4, prompt_len=6,
                            gen_tokens=6, block_size=4,
                            shared_prefix_len=4))
    # the multi-tenant row: bursty MMPP two-class trace under per-class
    # quotas + preemption — per-class p99/ttft and goodput-under-SLO
    rows.extend(two_class_rows(arch, quant=quant))
    # multi-model multiplexing: two dedicated engines vs one multiplexed
    # engine on the same per-model offered rates — the +2model row's
    # combined occupancy beats either +dedicated row's
    rows.extend(multiplex_rows(quant=quant))
    # the speculative rows, paired with the default rate-800 row above
    # (same arch, same trace) so the accepted_per_dispatch/ticks columns
    # show what draft-and-verify buys: the full-depth self-draft is the
    # mechanical upper bound (every proposal accepted, ticks cut by
    # ~spec_k+1), the 1-layer self-draft is the realistic cheap proposer
    # with partial acceptance — both bit-for-bit the non-spec outputs
    rows.extend(engine_rows(arch, quant=quant, rates=(800.0,), spec_k=3))
    rows.extend(engine_rows(arch, quant=quant, rates=(800.0,), spec_k=3,
                            draft_layers=1))
    # the fleet row: the same trace behind the replica router — two
    # engines, occupancy-projected placement, per-replica columns
    rows.extend(router_rows(arch, quant=quant))
    return rows


def _downsample(xs, n=32):
    if len(xs) <= n:
        return list(xs)
    step = (len(xs) - 1) / (n - 1)      # endpoints kept: the curve's
    return [xs[round(i * step)] for i in range(n)]   # drain-down is visible


def engine_rows(arch: str = "starcoder2-3b", *, quant: str = "w8a16",
                rates=(200.0, 800.0), n_requests: int = 24,
                num_slots: int = 8, prompt_len: int = 3,
                gen_tokens: int = 6, prefill_chunk: int = 4,
                block_size=None, num_blocks=None,
                shared_prefix_len: int = 0,
                spec_k: int = 0, draft_layers=None):
    """Continuous-batching engine rows: p99 + occupancy + admission-to-
    first-token vs offered rate, for any token-only decode family.
    ``block_size`` switches the engine to the paged KV cache (and
    ``shared_prefix_len`` gives the trace a common system prompt whose
    blocks the paged engine shares across requests).  ``spec_k`` turns on
    per-slot draft-and-verify speculative decoding with a truncated
    self-draft of ``draft_layers`` layers (default: full depth, the
    accept-everything upper bound)."""
    import jax

    from repro import engine as E
    from repro.configs import get_config
    from repro.core.qlinear import FP, W8A16, W8A8
    from repro.core.quant import quantize_tree
    from repro.models import registry as R

    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[quant]
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    if mode.enabled:
        params = quantize_tree(params, min_size=2048)
    dl = (draft_layers or cfg.n_layers) if spec_k else None
    eng = E.Engine(cfg, params, mode=mode, num_slots=num_slots,
                   max_seq=prompt_len + gen_tokens,   # Engine rounds up
                   prefill_chunk=prefill_chunk or None,
                   block_size=block_size, num_blocks=num_blocks,
                   spec_k=spec_k, draft_layers=dl)
    # encdec/vlm: per-request sources for the prime dispatch (their ttft
    # columns therefore include the prime cost)
    source_shape = R.source_shape(cfg)

    # warm the jit cache first (the first serve pays trace+compile), then
    # measure the real per-tick cost on a second wall-clock run and replay
    # each offered rate under the virtual clock at that tick cost —
    # deterministic shape, real steady-state timing
    warm_reqs = E.synthetic_requests(
        max(4, num_slots), rate_per_s=1e6, vocab=cfg.vocab,
        prompt_len=prompt_len, max_new_tokens=gen_tokens,
        source_shape=source_shape)
    eng.serve(warm_reqs, clock="wall")
    warm = eng.serve(warm_reqs, clock="wall")
    tick_s = warm.wall_s / max(warm.ticks, 1)

    rows = []
    for rate in rates:
        reqs = E.synthetic_requests(
            n_requests, rate_per_s=rate, vocab=cfg.vocab,
            prompt_len=prompt_len, max_new_tokens=gen_tokens,
            shared_prefix_len=shared_prefix_len,
            source_shape=source_shape)
        rep = eng.serve(reqs, clock="virtual", tick_s=tick_s)
        rows.append(_engine_row(cfg, rate, n_requests, rep,
                                draft_layers=dl or 0))
    return rows


def _engine_row(cfg, rate, n_requests, rep, draft_layers: int = 0,
                model=None, replicas: int = 1, tp: int = 1,
                replica_occupancy=None):
    """One BENCH engine row from an EngineReport (schema pinned by
    tests/test_bench_smoke.py).  ``model`` labels the row's lane story:
    a lane tag for a dedicated single-model engine in a multiplex
    comparison, a "+"-joined tag list for a multiplexed engine, None
    for ordinary single-model rows.  ``replicas``/``tp``/
    ``replica_occupancy`` are the fleet columns: 1/1/{} everywhere
    except the ``+router`` rows built by :func:`router_rows`."""
    return {
        # fleet columns (scale-out rows only; the defaults mean "one
        # engine, one device" — today's rows byte-identically)
        "replicas": replicas, "tp": tp,
        "replica_occupancy": dict(replica_occupancy or {}),
        "kind": "engine", "arch": cfg.name, "family": cfg.family,
        "model": model,
        # per-model columns (populated on multiplexed engines; empty
        # dicts everywhere else — the keys are always present)
        "model_p99_s": dict(rep.model_p99_latency_s),
        "model_mean_ttft_s": dict(rep.model_mean_ttft_s),
        "model_goodput_tokens_per_s": dict(rep.model_goodput_tokens_per_s),
        "model_mean_occupancy": dict(rep.model_mean_occupancy),
        "rate": rate,
        "n_requests": n_requests, "num_slots": rep.num_slots,
        "p99_s": rep.p99_latency_s,
        "tokens_per_s": rep.tokens_per_s,
        "mean_occupancy": rep.mean_occupancy,
        "ticks": rep.ticks,
        "admissions_while_busy": rep.admissions_while_busy,
        "occupancy_curve": _downsample(rep.occupancy),
        "prefill_chunk": rep.prefill_chunk,
        "mean_ttft_s": rep.mean_ttft_s,
        "p99_ttft_s": rep.p99_ttft_s,
        "block_size": rep.block_size,
        "num_blocks": rep.num_blocks,
        "kv_hbm_bytes": rep.kv_hbm_bytes,
        "peak_blocks_used": rep.peak_blocks_used,
        "mean_block_util": rep.mean_block_util,
        "shared_block_hits": rep.shared_block_hits,
        "shared_hit_rate": rep.shared_hit_rate,
        "prefill_tokens_skipped": rep.prefill_tokens_skipped,
        "effective_concurrency": rep.effective_concurrency,
        # overload robustness: per-SLO-class tails + the honest metric
        # at scale (goodput counts only completed-on-time requests)
        "class_p99_latency_s": dict(rep.class_p99_latency_s),
        "class_mean_ttft_s": dict(rep.class_mean_ttft_s),
        "class_p99_ttft_s": dict(rep.class_p99_ttft_s),
        "goodput_tokens_per_s": rep.goodput_tokens_per_s,
        "slo_attainment": rep.slo_attainment,
        "preempted": rep.preempted,
        "dropped": rep.dropped,
        "failed": rep.failed,
        "unfinished": rep.unfinished,
        # speculative decoding: tokens committed per verify dispatch
        # (exactly 1.0 when spec_k == 0 — the accounting's fixed point)
        # and the honest per-token latency that makes the win legible
        "spec_k": rep.spec_k,
        "draft_layers": draft_layers,
        "accepted_per_dispatch": rep.accepted_per_dispatch,
        "latency_per_token_s": rep.latency_per_token_s,
    }


def two_class_rows(arch: str = "starcoder2-3b", *, quant: str = "w8a16",
                   rate: float = 800.0, n_requests: int = 24,
                   num_slots: int = 4, batch_quota: int = 2):
    """The multi-tenant BENCH row: a bursty MMPP two-class trace served
    under per-class slot quotas with preemption on, so the per-class
    columns diverge (interactive holds its tail while batch absorbs the
    overload) and the goodput/SLO-attainment columns mean something."""
    import jax

    from benchmarks import traces as TR
    from repro import engine as E
    from repro.configs import get_config
    from repro.core import batching as bt
    from repro.core.qlinear import FP, W8A16, W8A8
    from repro.core.quant import quantize_tree
    from repro.models import registry as R

    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[quant]
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    if mode.enabled:
        params = quantize_tree(params, min_size=2048)
    policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=num_slots,
                                max_wait_s=0.0,
                                class_quotas={"batch": batch_quota})
    eng = E.Engine(cfg, params, mode=mode, num_slots=num_slots,
                   max_seq=24, prefill_chunk=4, block_size=4,
                   policy=policy)
    reqs = TR.two_class_trace(n_requests, rate_per_s=rate, vocab=cfg.vocab,
                              seed=0, interactive_deadline_s=0.05,
                              batch_deadline_s=2.0,
                              prompt_len=(2, 8), max_new_tokens=(2, 8))
    # first wall serve pays trace+compile; measure the real per-tick
    # cost on the second and replay under the virtual clock (same
    # discipline as engine_rows — deadlines are meaningless against a
    # tick cost that includes compilation)
    eng.serve(reqs[:num_slots], clock="wall")
    warm = eng.serve(reqs[:num_slots], clock="wall")
    tick_s = warm.wall_s / max(warm.ticks, 1)
    rep = eng.serve(reqs, clock="virtual", tick_s=tick_s, preemption=True)
    row = _engine_row(cfg, rate, n_requests, rep)
    row["arch"] = cfg.name + "+2class"
    return [row]


def _multiplex_pair(quant: str = "w8a16"):
    """The two lanes the multiplex BENCH/smoke stories share: a reduced
    dense arch and a reduced MoE arch with their params (quantized per
    ``quant``), as ``(tag, cfg, params)`` triples."""
    import jax

    from repro.configs import get_config
    from repro.core.qlinear import FP, W8A16, W8A8
    from repro.core.quant import quantize_tree
    from repro.models import registry as R

    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[quant]
    out = []
    for tag, arch, seed in (("a", "starcoder2-3b", 0),
                            ("b", "qwen2-moe-a2.7b", 1)):
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  kv_quant=True)
        params = R.init(jax.random.PRNGKey(seed), cfg)
        if mode.enabled:
            params = quantize_tree(params, min_size=2048)
        out.append((tag, cfg, params))
    return mode, out


def multiplex_rows(*, quant: str = "w8a16", rate: float = 600.0,
                   n_requests: int = 16, num_slots: int = 4):
    """The multi-model BENCH rows: each lane served by a dedicated
    engine at its offered rate (arch suffix ``+dedicated``), then BOTH
    lanes multiplexed on ONE engine at the SAME per-model offered rates
    (suffix ``+2model``).  The multiplexed row's combined occupancy must
    beat either dedicated row's — the whole point of leasing one slot
    budget across models instead of static partitioning; the smoke gate
    and tests/test_bench_smoke.py assert exactly that."""
    from repro import engine as E

    mode, pair = _multiplex_pair(quant)
    per_model = {}
    for tag, cfg, params in pair:
        per_model[tag] = E.synthetic_requests(
            n_requests, rate_per_s=rate, vocab=cfg.vocab, prompt_len=4,
            max_new_tokens=6, seed=ord(tag), model=tag)
    # unique rids across the merged trace; the dedicated replays serve
    # the SAME offset-rid requests so prompts match token for token
    per_model["b"] = [dataclasses.replace(r, rid=r.rid + 1000)
                      for r in per_model["b"]]

    rows = []
    dedicated_occ = {}
    for tag, cfg, params in pair:
        eng = E.Engine(cfg, params, mode=mode, num_slots=num_slots,
                       max_seq=16, prefill_chunk=4, block_size=4)
        sub = [dataclasses.replace(r, model=None) for r in per_model[tag]]
        eng.serve(sub[:num_slots], clock="wall")
        warm = eng.serve(sub[:num_slots], clock="wall")
        tick_s = warm.wall_s / max(warm.ticks, 1)
        rep = eng.serve(sub, clock="virtual", tick_s=tick_s)
        dedicated_occ[tag] = rep.mean_occupancy
        row = _engine_row(cfg, rate, n_requests, rep, model=tag)
        row["arch"] = cfg.name + "+dedicated"
        rows.append(row)

    meng = E.Engine(models={t: (c, p) for t, c, p in pair}, mode=mode,
                    num_slots=num_slots, max_seq=16, prefill_chunk=4,
                    block_size=4)
    merged = sorted(per_model["a"] + per_model["b"],
                    key=lambda r: r.arrival_s)
    meng.serve(merged[:num_slots], clock="wall")
    warm = meng.serve(merged[:num_slots], clock="wall")
    tick_s = warm.wall_s / max(warm.ticks, 1)
    mrep = meng.serve(merged, clock="virtual", tick_s=tick_s)
    if mrep.mean_occupancy <= max(dedicated_occ.values()):
        raise AssertionError(
            f"multiplexed occupancy {mrep.mean_occupancy:.3f} does not "
            f"beat the dedicated engines' {dedicated_occ} at the same "
            "offered rates — slot leasing is not consolidating load")
    row = _engine_row(pair[0][1], 2 * rate, 2 * n_requests, mrep,
                      model="a+b")
    row["arch"] = pair[0][1].name + "+2model"
    rows.append(row)
    return rows


def _router_row(cfg, rate, n_requests, rrep, *, replicas, tp,
                draft_layers=0):
    """One BENCH engine row for a routed fleet: fleet-level tails and
    throughput from the RouterReport, capacity/accounting columns summed
    or averaged across the per-replica EngineReports, and the fleet
    columns (``replicas``/``tp``/``replica_occupancy``) filled in."""
    import numpy as np

    from repro.core import batching as bt

    reps = list(rrep.replicas.values())
    row = _engine_row(cfg, rate, n_requests, reps[0],
                      draft_layers=draft_layers, replicas=replicas,
                      tp=tp, replica_occupancy=rrep.replica_occupancy)
    mean = lambda xs: float(np.mean(xs))
    ttfts = [r.ttft_s for r in rrep.results if r.emitted]
    row.update({
        "p99_s": rrep.p99_latency_s,
        "tokens_per_s": rrep.tokens_per_s,
        "goodput_tokens_per_s": rrep.goodput_tokens_per_s,
        "mean_ttft_s": rrep.mean_ttft_s,
        "p99_ttft_s": bt.p99(ttfts),
        "ticks": sum(r.ticks for r in reps),
        "admissions_while_busy": sum(r.admissions_while_busy
                                     for r in reps),
        "mean_occupancy": mean([r.mean_occupancy for r in reps]),
        "occupancy_curve": _downsample(
            [x for r in reps for x in r.occupancy]),
        "kv_hbm_bytes": sum(r.kv_hbm_bytes for r in reps),
        "peak_blocks_used": max(r.peak_blocks_used for r in reps),
        "mean_block_util": mean([r.mean_block_util for r in reps]),
        "shared_block_hits": sum(r.shared_block_hits for r in reps),
        "shared_hit_rate": mean([r.shared_hit_rate for r in reps]),
        "prefill_tokens_skipped": sum(r.prefill_tokens_skipped
                                      for r in reps),
        "effective_concurrency": sum(r.effective_concurrency
                                     for r in reps),
        "slo_attainment": mean([r.slo_attainment for r in reps]),
        "preempted": sum(r.preempted for r in reps),
        "dropped": sum(r.dropped for r in reps),
        "failed": sum(r.failed for r in reps),
        "unfinished": sum(r.unfinished for r in reps),
        "accepted_per_dispatch": mean([r.accepted_per_dispatch
                                       for r in reps]),
        "latency_per_token_s": mean([r.latency_per_token_s
                                     for r in reps]),
    })
    # per-class tails: the fleet's honest (conservative) view is the
    # worst replica's tail per class
    for key in ("class_p99_latency_s", "class_mean_ttft_s",
                "class_p99_ttft_s"):
        merged = {}
        for r in reps:
            for cls, v in getattr(r, key).items():
                merged[cls] = max(merged.get(cls, 0.0), v)
        row[key] = merged
    return row


def router_rows(arch: str = "starcoder2-3b", *, quant: str = "w8a16",
                rate: float = 800.0, n_requests: int = 32,
                num_slots: int = 4, replicas: int = 2):
    """The ``+router`` BENCH row: the engine trace served by a
    :class:`repro.engine.ReplicaRouter` over ``replicas`` identically-
    configured engines — same virtual-clock discipline as
    ``engine_rows`` (tick cost measured on one warmed replica, then the
    fleet replayed deterministically), with per-replica occupancy in
    the fleet columns."""
    import jax

    from repro import engine as E
    from repro.configs import get_config
    from repro.core.qlinear import FP, W8A16, W8A8
    from repro.core.quant import quantize_tree
    from repro.models import registry as R

    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[quant]
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    if mode.enabled:
        params = quantize_tree(params, min_size=2048)
    engines = [E.Engine(cfg, params, mode=mode, num_slots=num_slots,
                        max_seq=16, prefill_chunk=4, block_size=4)
               for _ in range(replicas)]
    warm_reqs = E.synthetic_requests(num_slots, rate_per_s=1e6,
                                     vocab=cfg.vocab, prompt_len=3,
                                     max_new_tokens=6)
    engines[0].serve(warm_reqs, clock="wall")
    warm = engines[0].serve(warm_reqs, clock="wall")
    tick_s = warm.wall_s / max(warm.ticks, 1)
    router = E.ReplicaRouter(engines)
    reqs = E.synthetic_requests(n_requests, rate_per_s=rate,
                                vocab=cfg.vocab, prompt_len=3,
                                max_new_tokens=6)
    rrep = router.serve(reqs, clock="virtual", tick_s=tick_s)
    if rrep.refused:
        raise AssertionError(f"router BENCH row refused {rrep.refused} "
                             "requests on an uncapped fleet")
    row = _router_row(cfg, rate, n_requests, rrep, replicas=replicas,
                      tp=1)
    row["arch"] = cfg.name + "+router"
    return [row]


def router_smoke() -> dict:
    """The fleet gate (``benchmarks/run.py --smoke``): 2 replicas x 2
    model lanes behind the replica router, a bursty two-model two-class
    trace with preemption and tight per-lane block pools.  The
    invariants:

    - routed outputs are bit-for-bit each lane's own sequential
      reference (placement is invisible in the tokens: replicas share
      no device state — decode-contract rule 9);
    - nothing is lost (one typed result per request) and every
      replica's block pools drain clean (``leaked_blocks == 0``
      summed over the fleet);
    - both replicas actually took work (the projection spreads load
      instead of degenerating to replica 0)."""
    from benchmarks import traces as TR
    from repro import engine as E

    mode, pair = _multiplex_pair("w8a16")
    cfgs = {t: c for t, c, _ in pair}
    prms = {t: p for t, _, p in pair}
    reqs = TR.two_class_trace(
        160, rate_per_s=2000.0, vocab=0, seed=7,
        interactive_deadline_s=1e9, batch_deadline_s=1e9,
        prompt_len=(2, 8), max_new_tokens=(2, 6),
        arrival=TR.mmpp_process(dwell_s=(0.05, 0.0125)),
        models=[(t, cfgs[t].vocab) for t, _, _ in pair])
    want = {}
    for t in cfgs:
        sub = [dataclasses.replace(r, model=None)
               for r in reqs if r.model == t]
        want[t] = E.reference_outputs(cfgs[t], prms[t], sub, max_seq=16)

    engines = [E.Engine(models={t: (cfgs[t], prms[t]) for t in cfgs},
                        mode=mode, num_slots=4, max_seq=16,
                        prefill_chunk=4, block_size=4, num_blocks=13)
               for _ in range(2)]
    router = E.ReplicaRouter(engines)
    rep = router.serve(reqs, clock="virtual", tick_s=1e-3,
                       preemption=True)
    if len(rep.results) != len(reqs):
        raise AssertionError(
            f"router smoke lost requests: {len(rep.results)}/{len(reqs)}")
    if rep.refused:
        raise AssertionError(f"router smoke refused {rep.refused} "
                             "requests on an uncapped fleet")
    if rep.leaked_blocks != 0:
        raise AssertionError(f"router smoke leaked {rep.leaked_blocks} "
                             "KV blocks across the fleet")
    if min(rep.replica_requests.values()) <= 0:
        raise AssertionError(
            f"router smoke starved a replica: {rep.replica_requests}")
    bad = [r.rid for r in rep.results
           if r.status == "ok" and r.tokens != want[r.model][r.rid]]
    if bad:
        raise AssertionError(
            f"routed outputs diverge from per-model references for rids "
            f"{bad[:8]} — placement is not invisible in the tokens")
    return {"requests": len(rep.results),
            "replicas": len(engines),
            "replica_requests": dict(rep.replica_requests),
            "replica_occupancy": {n: round(v, 3) for n, v
                                  in rep.replica_occupancy.items()},
            "preempted": sum(r.preempted for r in rep.replicas.values()),
            "leaked_blocks": rep.leaked_blocks,
            "goodput_tokens_per_s": rep.goodput_tokens_per_s}


def sharded_smoke() -> dict:
    """The tensor-parallel gate (``benchmarks/run.py --smoke``): the
    sharded executor must be bit-for-bit the single-device engine on
    the same trace.  With one visible device the tp=1 conformance pair
    still runs (same shard_map plumbing, 1-way mesh); the multi-device
    pair needs a forced host mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=4``, set before
    jax starts) and reports itself skipped otherwise — the full
    per-family 200-request gates live in tests/test_sharded.py."""
    import jax

    from repro import engine as E
    from repro.configs import get_config
    from repro.models import registry as R
    from repro.runtime import steps as ST

    if not ST.supports_sharded_serving():
        return {"skipped": "no jax.experimental.shard_map in this jax"}
    ndev = len(jax.devices())
    tp = min(4, ndev)
    cfg = dataclasses.replace(
        get_config("starcoder2-3b").reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    reqs = E.synthetic_requests(24, rate_per_s=2000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=5)
    kw = dict(num_slots=4, max_seq=16, prefill_chunk=2, block_size=4)
    r1 = E.Engine(cfg, params, **kw).serve(reqs, tick_s=1e-3)
    r2 = E.Engine(cfg, params, backend=E.ShardedExecutor(tp=tp),
                  **kw).serve(reqs, tick_s=1e-3)
    if r1.outputs() != r2.outputs():
        raise AssertionError(
            f"sharded executor (tp={tp}) outputs diverge from the "
            "single-device engine — slot-axis sharding lost bit parity")
    return {"tp": tp, "devices": ndev,
            "requests": len(r2.results),
            "multi_device": tp > 1,
            "skipped_multi": (None if tp > 1 else
                              "1 visible device; force a mesh with "
                              "XLA_FLAGS="
                              "--xla_force_host_platform_device_count=4")}


def multiplex_smoke() -> dict:
    """The multi-model gate (``benchmarks/run.py --smoke``): two model
    families multiplexed on one engine through a bursty two-model
    two-class trace, with preemption, an under-provisioned per-lane
    block pool, and a seeded fault plan striking across lanes.  The
    invariants:

    - per-model outputs are bit-for-bit each lane's own sequential
      reference for every non-failed completed request (cross-model
      interleaving, preemption, and faults are invisible in the tokens);
    - nothing is lost (one typed result per request) and both lanes'
      block pools drain clean (``leaked_blocks == 0`` summed);
    - prefix keys are model-fingerprinted (the same token prompt hashes
      to different chains on different lanes), so paged sharing cannot
      cross models even before the lane-private pools make it
      structurally impossible;
    - the multiplexed occupancy consolidation holds (the
      ``multiplex_rows`` comparison runs as part of the gate)."""
    from benchmarks import traces as TR
    from repro import engine as E

    mode, pair = _multiplex_pair("w8a16")
    cfgs = {t: c for t, c, _ in pair}
    prms = {t: p for t, _, p in pair}
    reqs = TR.two_class_trace(
        160, rate_per_s=2000.0, vocab=0, seed=7,
        interactive_deadline_s=1e9, batch_deadline_s=1e9,
        prompt_len=(2, 8), max_new_tokens=(2, 6),
        arrival=TR.mmpp_process(dwell_s=(0.05, 0.0125)),
        models=[(t, cfgs[t].vocab) for t, _, _ in pair])
    want = {}
    for t in cfgs:
        sub = [dataclasses.replace(r, model=None)
               for r in reqs if r.model == t]
        want[t] = E.reference_outputs(cfgs[t], prms[t], sub, max_seq=16)

    eng = E.Engine(models={t: (cfgs[t], prms[t]) for t in cfgs},
                   mode=mode, num_slots=4, max_seq=16, prefill_chunk=4,
                   block_size=4, num_blocks=13)
    plan = E.FaultPlan.random(seed=42, n_faults=12, max_tick=300,
                              num_slots=8)   # global ids span 2 lanes
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3, preemption=True,
                    fault_plan=plan)
    if len(rep.results) != len(reqs):
        raise AssertionError(
            f"multiplex smoke lost requests: {len(rep.results)}/{len(reqs)}")
    if rep.leaked_blocks != 0:
        raise AssertionError(f"multiplex smoke leaked {rep.leaked_blocks} "
                             "KV blocks across lanes")
    if rep.preempted <= 0:
        raise AssertionError("multiplex smoke never preempted: the "
                             "per-lane block pools are not tight enough")
    if not plan.fired:
        raise AssertionError("no scheduled fault fired across the lanes")
    bad = [r.rid for r in rep.results
           if r.status == "ok" and r.tokens != want[r.model][r.rid]]
    if bad:
        raise AssertionError(
            f"multiplexed outputs diverge from per-model references for "
            f"rids {bad[:8]} — model state is leaking across lanes")
    probe = next(r for r in reqs
                 if r.model == "a" and len(r.prompt) >= 4)
    ka = eng.lanes["a"]._prefix_keys(dataclasses.replace(probe, model=None))
    kb = eng.lanes["b"]._prefix_keys(dataclasses.replace(probe, model=None))
    if ka == kb:
        raise AssertionError("prefix keys are not model-fingerprinted: "
                             "identical prompts hash equal across lanes")
    mrows = multiplex_rows(quant="w8a16")
    occ = {r["arch"].rsplit("+", 1)[1]: r["mean_occupancy"]
           for r in mrows}
    return {"requests": len(rep.results),
            "preempted": rep.preempted,
            "failed": rep.failed,
            "faults_fired": len(plan.fired),
            "leaked_blocks": rep.leaked_blocks,
            "model_mean_occupancy": dict(rep.model_mean_occupancy),
            "multiplexed_occupancy": occ.get("2model"),
            "goodput_tokens_per_s": rep.goodput_tokens_per_s}


def engine_smoke(n_requests: int = 12) -> dict:
    """Offline smoke: a short continuous-batching run whose outputs must
    match the sequential per-token reference bit-for-bit (per-token AND
    chunked prefill; dense AND a recurrent family AND an
    encoder-conditioned family through its prime dispatch), plus an
    interpret-mode parity check of the fused decode-attention kernel's
    append path (current-token k/v operand).  Exercised by
    ``benchmarks/run.py --smoke`` so cost-engine or kernel regressions
    surface in the smoke gate.

    The paged-KV gates ride along: (1) a 200-request pseudo-Poisson
    shared-prefix trace served from KV blocks behind per-slot block
    tables (chunked prefill, slot AND block reuse, shared-prefix blocks
    refcounted across tenants) must match the sequential reference
    bit-for-bit; (2) so must a prime family (whisper) through the same
    paged path; (3) a trace whose live requests exceed what the block
    budget could hold contiguously must complete under blocks-limited
    admission; (4) the block-gather decode-attention kernel must match
    ``kernels/ref.py`` under the Pallas interpreter."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import engine as E
    from repro.configs import get_config
    from repro.kernels import ops, ref
    from repro.models import registry as R

    cfg = dataclasses.replace(
        get_config("starcoder2-3b").reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    reqs = E.synthetic_requests(n_requests, rate_per_s=2000.0,
                                vocab=cfg.vocab, prompt_len=3,
                                max_new_tokens=5)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)
    # explicit raises (not asserts): the gate must hold under python -O
    if rep.outputs() != want:
        raise AssertionError("engine outputs != sequential reference")
    if rep.admissions_while_busy <= 0:
        raise AssertionError("no mid-generation admissions: the engine "
                             "is not batching continuously")
    chunked = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2)
    repc = chunked.serve(reqs, clock="virtual", tick_s=1e-3)
    if repc.outputs() != want:
        raise AssertionError("chunked-prefill outputs != per-token "
                             "reference")
    if repc.mean_ttft_s >= rep.mean_ttft_s:
        raise AssertionError("chunked prefill did not cut "
                             "admission-to-first-token")
    # a recurrent family through the same slot engine (reset-at-zero
    # scrub + frozen inactive state)
    scfg = get_config("mamba2-1.3b").reduced()
    sparams = R.init(jax.random.PRNGKey(1), scfg)
    sreqs = E.synthetic_requests(6, rate_per_s=2000.0, vocab=scfg.vocab,
                                 prompt_len=4, max_new_tokens=3)
    srep = E.Engine(scfg, sparams, num_slots=2, max_seq=16,
                    prefill_chunk=2).serve(sreqs, clock="virtual",
                                           tick_s=1e-3)
    if srep.outputs() != E.reference_outputs(scfg, sparams, sreqs,
                                             max_seq=16):
        raise AssertionError("ssm engine outputs != sequential reference")
    # an encoder-conditioned family through the same slot engine: a prime
    # dispatch writes each request's cross-K/V into its slot row at
    # admission, and slot reuse across tenants must stay bit-for-bit
    wcfg = get_config("whisper-medium").reduced()
    wparams = R.init(jax.random.PRNGKey(2), wcfg)
    wreqs = E.synthetic_requests(
        6, rate_per_s=2000.0, vocab=wcfg.vocab, prompt_len=3,
        max_new_tokens=3, source_shape=R.source_shape(wcfg))
    wrep = E.Engine(wcfg, wparams, num_slots=2, max_seq=16).serve(
        wreqs, clock="virtual", tick_s=1e-3)
    if wrep.outputs() != E.reference_outputs(wcfg, wparams, wreqs,
                                             max_seq=16):
        raise AssertionError("encdec engine outputs != sequential "
                             "reference (primed cross-K/V slot path)")

    # ---- paged-KV gates ----------------------------------------------
    # (1) the acceptance trace: 200 pseudo-Poisson requests with a shared
    # system-prompt prefix through the paged engine (blocks + tables +
    # chunked prefill + refcounted prefix sharing), bit-for-bit vs the
    # sequential reference, with slot reuse AND block reuse exercised
    preqs = E.synthetic_requests(200, rate_per_s=2000.0, vocab=cfg.vocab,
                                 prompt_len=6, max_new_tokens=5,
                                 shared_prefix_len=4)
    pwant = E.reference_outputs(cfg, params, preqs, max_seq=16)
    peng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                    prefill_chunk=4, block_size=4)
    prep = peng.serve(preqs, clock="virtual", tick_s=1e-3)
    if prep.outputs() != pwant:
        raise AssertionError("paged engine outputs != sequential "
                             "reference (200-request shared-prefix trace)")
    if prep.shared_block_hits <= 0:
        raise AssertionError("paged engine shared no prefix blocks on a "
                             "shared-prefix trace")
    if prep.admissions_while_busy <= 0:
        raise AssertionError("paged engine admitted nothing mid-flight")
    # (2) a prime family (encdec) through the same paged path
    pwrep = E.Engine(wcfg, wparams, num_slots=2, max_seq=16,
                     prefill_chunk=2, block_size=4).serve(
        wreqs, clock="virtual", tick_s=1e-3)
    if pwrep.outputs() != E.reference_outputs(wcfg, wparams, wreqs,
                                              max_seq=16):
        raise AssertionError("paged encdec outputs != sequential "
                             "reference")
    # (3) blocks-limited admission: 8 slots but only 16 usable blocks
    # (what 4 contiguous rows would hold) — more live requests than the
    # contiguous pool could serve, and every request still completes
    lreqs = E.synthetic_requests(20, rate_per_s=5000.0, vocab=cfg.vocab,
                                 prompt_len=6, max_new_tokens=5)
    lwant = E.reference_outputs(cfg, params, lreqs, max_seq=16)
    lrep = E.Engine(cfg, params, num_slots=8, max_seq=16, prefill_chunk=4,
                    block_size=4, num_blocks=17).serve(
        lreqs, clock="virtual", tick_s=1e-3)
    if lrep.outputs() != lwant or len(lrep.results) != len(lreqs):
        raise AssertionError("blocks-limited paged engine failed to "
                             "complete the trace bit-for-bit")
    if max(lrep.occupancy) <= 4:
        raise AssertionError("blocks-limited trace never exceeded the "
                             "contiguous-equivalent concurrency")
    if lrep.peak_blocks_used > 16:
        raise AssertionError("paged engine overran the block budget")

    # (4) block-gather kernel parity, Pallas interpreter (offline-safe)
    rng = np.random.default_rng(3)
    nb, bs_, bq, mb, kvp, gq, hdp = 5, 128, 2, 2, 2, 2, 64
    pq = jnp.asarray(rng.standard_normal((bq, kvp, gq, hdp)), jnp.float32)
    pk = jnp.asarray(rng.integers(-127, 127, (nb, bs_, kvp, hdp)), jnp.int8)
    pv = jnp.asarray(rng.integers(-127, 127, (nb, bs_, kvp, hdp)), jnp.int8)
    pks = jnp.asarray(rng.uniform(.005, .05, (nb, bs_, kvp, 1)), jnp.float32)
    pvs = jnp.asarray(rng.uniform(.005, .05, (nb, bs_, kvp, 1)), jnp.float32)
    pvl = jnp.asarray([200, 130], jnp.int32)
    ptbl = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    pkn = jnp.asarray(rng.standard_normal((bq, kvp, 1, hdp)), jnp.float32)
    pvn = jnp.asarray(rng.standard_normal((bq, kvp, 1, hdp)), jnp.float32)
    pgot = ops.decode_attention(pq, pk, pv, pks, pvs, pvl,
                                block_tables=ptbl, k_new=pkn, v_new=pvn,
                                interpret=True)
    poracle = ref.decode_attention_paged_ref(pq, pk, pv, pks, pvs, pvl,
                                             ptbl, k_new=pkn, v_new=pvn)
    np.testing.assert_allclose(np.asarray(pgot), np.asarray(poracle),
                               rtol=2e-5, atol=2e-5)

    # append-path kernel parity, Pallas interpreter (offline-safe)
    ks = jax.random.split(jax.random.PRNGKey(1), 7)
    b, s, kv, g, hd = 1, 128, 2, 2, 64
    q = jax.random.normal(ks[0], (b, kv, g, hd), jnp.float32)
    kc = jax.random.randint(ks[1], (b, s, kv, hd), -127, 127, jnp.int8)
    vc = jax.random.randint(ks[2], (b, s, kv, hd), -127, 127, jnp.int8)
    ksc = jax.random.uniform(ks[3], (b, s, kv, 1), jnp.float32, .005, .05)
    vsc = jax.random.uniform(ks[4], (b, s, kv, 1), jnp.float32, .005, .05)
    kn = jax.random.normal(ks[5], (b, 1, kv, hd), jnp.float32)
    vn = jax.random.normal(ks[6], (b, 1, kv, hd), jnp.float32)
    got = ops.decode_attention(q, kc, vc, ksc, vsc, jnp.int32(77),
                               k_new=kn, v_new=vn, interpret=True)
    oracle = ref.decode_attention_int8_ref(q, kc, vc, ksc, vsc,
                                           jnp.int32(77), k_new=kn,
                                           v_new=vn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=2e-5, atol=2e-5)
    return {"requests": len(rep.results), "ticks": rep.ticks,
            "mean_occupancy": rep.mean_occupancy,
            "admissions_while_busy": rep.admissions_while_busy,
            "mean_ttft_s": rep.mean_ttft_s,
            "chunked_mean_ttft_s": repc.mean_ttft_s,
            "paged_requests": len(prep.results),
            "paged_shared_block_hits": prep.shared_block_hits,
            "paged_prefill_tokens_skipped": prep.prefill_tokens_skipped,
            "paged_limited_peak_occupancy": max(lrep.occupancy)}


def chaos_smoke(n_requests: int = 200) -> dict:
    """The overload/fault gate (``benchmarks/run.py --smoke``): a
    bursty MMPP two-class trace with seeded faults and forced
    preemptions through a deliberately under-provisioned paged engine.
    Must complete with zero uncaught exceptions, and the invariants
    must hold:

    - every request gets exactly one typed result (nothing lost);
    - the block pool drains clean (``leaked_blocks == 0``);
    - preemptions and faults actually fired (the run exercised the
      machinery, not an idle pass);
    - every non-failed completed request's output is bit-for-bit its
      sequential reference (exact resume under chaos);
    - the control arm — same trace, no faults, no preemption, ample
      blocks — stays bit-for-bit the reference too (the machinery
      costs nothing when off).
    """
    import jax

    from benchmarks import traces as TR
    from repro import engine as E
    from repro.configs import get_config
    from repro.core import batching as bt
    from repro.models import registry as R

    cfg = dataclasses.replace(
        get_config("starcoder2-3b").reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    # dwell times scaled to the trace's ~0.1 s horizon so the MMPP
    # actually switches states (the 0.5 s defaults model second-scale
    # burst cycles and would look constant-rate here)
    reqs = TR.two_class_trace(n_requests, rate_per_s=2000.0,
                              vocab=cfg.vocab, seed=7,
                              interactive_deadline_s=1e9,
                              batch_deadline_s=1e9,
                              prompt_len=(2, 8), max_new_tokens=(2, 6),
                              arrival=TR.mmpp_process(
                                  dwell_s=(0.05, 0.0125)))
    times = [r.arrival_s for r in reqs]
    if TR.index_of_dispersion(times, window_s=0.01) <= 1.2:
        raise AssertionError("chaos trace is not bursty (IoD <= 1.2); "
                             "MMPP parameters broken?")
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)

    # chaos arm: tight block pool (forces preemption under pressure),
    # seeded fault plan (dispatch + nan + torn-table), class quotas
    policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=4,
                                max_wait_s=0.0, class_quotas={"batch": 2})
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=4,
                   block_size=4, num_blocks=13, policy=policy)
    plan = E.FaultPlan.random(seed=42, n_faults=12, max_tick=300,
                              num_slots=4)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3, preemption=True,
                    fault_plan=plan)
    if len(rep.results) != n_requests:
        raise AssertionError(
            f"chaos arm lost requests: {len(rep.results)}/{n_requests}")
    if rep.leaked_blocks != 0:
        raise AssertionError(f"chaos arm leaked {rep.leaked_blocks} "
                             "KV blocks")
    if rep.preempted <= 0:
        raise AssertionError("chaos arm never preempted: the block pool "
                             "is not tight enough to exercise eviction")
    if not plan.fired:
        raise AssertionError("no scheduled fault fired: the plan's ticks "
                             "miss the run entirely")
    bad = [r.rid for r in rep.results
           if r.status == "ok" and r.tokens != want[r.rid]]
    if bad:
        raise AssertionError(
            f"chaos arm outputs diverge from reference for rids {bad[:8]}"
            " — exact resume is broken")

    # control arm: same trace, machinery off, ample resources —
    # bit-for-bit parity, nothing preempted, nothing failed
    ctl = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=4,
                   block_size=4)
    crep = ctl.serve(reqs, clock="virtual", tick_s=1e-3)
    if crep.outputs() != want:
        raise AssertionError("control arm != sequential reference")
    if crep.preempted or crep.failed or crep.dropped:
        raise AssertionError("control arm triggered robustness machinery "
                             "with faults off")
    return {"requests": len(rep.results),
            "preempted": rep.preempted,
            "failed": rep.failed,
            "faults_fired": len(plan.fired),
            "dispatch_retries": rep.dispatch_retries,
            "nonfinite_samples": rep.nonfinite_samples,
            "torn_rows_repaired": rep.torn_rows_repaired,
            "leaked_blocks": rep.leaked_blocks,
            "goodput_tokens_per_s": rep.goodput_tokens_per_s,
            "slo_attainment": rep.slo_attainment}


def spec_smoke(n_requests: int = 60) -> dict:
    """The speculative-decoding gate (``benchmarks/run.py --smoke``):
    per-slot draft-and-verify must be invisible in the tokens.  Three
    arms, all against the same sequential per-token reference:

    - the full-depth self-draft chaos arm: draft == target, so every
      proposal agrees with the verifier, while a tight paged block pool,
      forced preemptions, and a seeded fault plan tear speculation
      mid-flight — in-flight proposals are uncommitted work, so every
      non-failed output must still be bit-for-bit the reference and the
      block pool must drain clean;
    - the garbage-draft arm: a draft initialised from a different seed
      proposes near-random tokens — acceptance collapses toward 1.0 but
      outputs stay exactly the reference (rejected KV writes are dead);
    - the non-spec control arm: ``spec_k=0`` on the same trace —
      ``accepted_per_dispatch`` exactly 1.0 and strictly more decode
      ticks than the clean full-depth run recorded in the BENCH rows.
    """
    import jax

    from repro import engine as E
    from repro.configs import get_config
    from repro.models import registry as R

    cfg = dataclasses.replace(
        get_config("starcoder2-3b").reduced(), kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    reqs = E.synthetic_requests(
        n_requests, rate_per_s=2000.0, vocab=cfg.vocab, prompt_len=3,
        max_new_tokens=5,
        priority=lambda rid: "batch" if rid % 3 == 0 else "interactive")
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)

    # chaos arm: full-depth self-draft under a tight block pool with
    # preemption and seeded faults — speculation torn mid-round must
    # leave nothing committed
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=2,
                   block_size=4, num_blocks=9, spec_k=3,
                   draft_layers=cfg.n_layers)
    plan = E.FaultPlan.random(seed=11, n_faults=8, max_tick=250,
                              num_slots=4)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3, preemption=True,
                    fault_plan=plan)
    if len(rep.results) != n_requests:
        raise AssertionError(
            f"spec chaos arm lost requests: {len(rep.results)}/{n_requests}")
    bad = [r.rid for r in rep.results
           if r.status == "ok" and r.tokens != want[r.rid]]
    if bad:
        raise AssertionError(
            f"spec chaos arm outputs diverge from reference for rids "
            f"{bad[:8]} — speculative state leaked across a preemption "
            "or fault")
    if rep.leaked_blocks != 0:
        raise AssertionError(f"spec chaos arm leaked {rep.leaked_blocks} "
                             "KV blocks")
    if rep.preempted <= 0:
        raise AssertionError("spec chaos arm never preempted: speculation "
                             "was not torn mid-flight")
    if not plan.fired:
        raise AssertionError("no scheduled fault fired during the spec "
                             "chaos arm")
    if rep.accepted_per_dispatch <= 1.0:
        raise AssertionError(
            f"full-depth self-draft committed only "
            f"{rep.accepted_per_dispatch:.2f} tokens/dispatch — "
            "acceptance is broken")

    # garbage-draft arm: the draft proposes noise; rejection must be
    # total recovery (dead KV writes, exact outputs)
    gparams = R.init(jax.random.PRNGKey(666), cfg)
    geng = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=4,
                    block_size=4, spec_k=3, draft=(cfg, gparams))
    grep = geng.serve(reqs, clock="virtual", tick_s=1e-3)
    if grep.outputs() != want:
        raise AssertionError("garbage-draft outputs != sequential "
                             "reference — rejected KV writes are live")
    if grep.accepted_per_dispatch < 1.0:
        raise AssertionError("accepted_per_dispatch < 1.0: dispatch "
                             "accounting is broken")

    # control arm: spec_k=0, same trace — apd is exactly 1.0 and the
    # outputs match (the machinery costs nothing when off)
    ctl = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=4,
                   block_size=4)
    crep = ctl.serve(reqs, clock="virtual", tick_s=1e-3)
    if crep.outputs() != want:
        raise AssertionError("spec control arm != sequential reference")
    if crep.accepted_per_dispatch != 1.0:
        raise AssertionError(
            f"non-speculative accepted_per_dispatch is "
            f"{crep.accepted_per_dispatch}, must be exactly 1.0")
    return {"requests": len(rep.results),
            "preempted": rep.preempted,
            "faults_fired": len(plan.fired),
            "failed": rep.failed,
            "leaked_blocks": rep.leaked_blocks,
            "chaos_accepted_per_dispatch": rep.accepted_per_dispatch,
            "garbage_accepted_per_dispatch": grep.accepted_per_dispatch,
            "control_ticks": crep.ticks,
            "latency_per_token_ms": rep.latency_per_token_s * 1e3}


def rows():
    """CSV-style rows for benchmarks/run.py's default suite."""
    out = []
    for r in serving_rows():
        if r["kind"] == "tokens_per_s":
            out.append((f"serving/decode_tps_b{r['batch']}",
                        r["seconds"] * 1e6,
                        f"tokens_per_s={r['tokens_per_s']:.0f}"))
        elif r["kind"] == "service_time":
            out.append((f"serving/service_b{r['batch']}",
                        r["seconds"] * 1e6, "prefill"))
        elif r["kind"] == "engine":
            out.append((f"serving/engine_rate{int(r['rate'])}",
                        r["p99_s"] * 1e6,
                        f"tokens_per_s={r['tokens_per_s']:.0f} "
                        f"occupancy={r['mean_occupancy']:.2f}"))
    return out


ALL = [rows]
