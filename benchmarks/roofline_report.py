"""Roofline report over the dry-run results (deliverable g).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits one
row per (arch x shape x mesh) cell with the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction —
plus, per cell, the per-op FLOP/byte breakdown recorded by the structural
HLO cost engine (``CostTotals.by_op``) so the report shows *where* the
counts come from.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.core.roofline import op_rows_from_by_op  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir: str = RESULTS, mesh: str = None, tag=""):
    cells = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("status") != "ok":
            continue
        parts = d["cell"].split("/")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def op_rows(cell: dict, top: int = 6):
    """Heaviest (opcode, flops, bytes, count) rows of one cell's by_op."""
    return op_rows_from_by_op(cell.get("by_op"), limit=top)


def rows(results_dir: str = RESULTS):
    out = []
    for d in load_cells(results_dir):
        step_us = max(d["compute_s"], d["memory_s"], d["collective_s"]) * 1e6
        out.append((f"roofline/{d['cell']}", step_us,
                    f"compute_s={d['compute_s']:.3e} "
                    f"memory_s={d['memory_s']:.3e} "
                    f"collective_s={d['collective_s']:.3e} "
                    f"bound={d['bound']} "
                    f"useful_frac={d['useful_flops_frac']:.2f} "
                    f"roofline_frac={d['roofline_frac']:.3f}"))
        for op, flops, byts, count in op_rows(d):
            out.append((f"roofline/{d['cell']}/op/{op}", 0.0,
                        f"flops={flops:.3e} bytes={byts:.3e} "
                        f"count={count:.0f}"))
    if not out:
        out.append(("roofline/none", 0.0,
                    "run `python -m repro.launch.dryrun` first"))
    return out


def markdown_table(results_dir: str = RESULTS, mesh: str = "single",
                   tag: str = "") -> str:
    cells = load_cells(results_dir, mesh=mesh, tag=tag)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "MODEL/HLO flops | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"])):
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']:.3e} | "
            f"{d['memory_s']:.3e} | {d['collective_s']:.3e} | "
            f"{d['bound']} | {d['useful_flops_frac']:.2f} | "
            f"{d['roofline_frac']:.3f} |")
    return "\n".join(lines)


def breakdown_table(results_dir: str = RESULTS, mesh: str = "single",
                    tag: str = "", top: int = 6) -> str:
    """Per-op FLOP/byte breakdown per cell, from CostTotals.by_op."""
    cells = load_cells(results_dir, mesh=mesh, tag=tag)
    lines = [
        "| cell | op | flops | bytes | count |",
        "|---|---|---|---|---|",
    ]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"])):
        for op, flops, byts, count in op_rows(d, top=top):
            lines.append(f"| {d['cell']} | {op} | {flops:.3e} | "
                         f"{byts:.3e} | {count:.0f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
    print()
    print("Per-op breakdown (from hlo_cost CostTotals.by_op):")
    print(breakdown_table())
