"""Roofline report over the dry-run results (deliverable g).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits one
row per (arch x shape x mesh) cell with the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir: str = RESULTS, mesh: str = None, tag=""):
    cells = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("status") != "ok":
            continue
        parts = d["cell"].split("/")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        if mesh and d.get("mesh") != mesh:
            continue
        cells.append(d)
    return cells


def rows(results_dir: str = RESULTS):
    out = []
    for d in load_cells(results_dir):
        step_us = max(d["compute_s"], d["memory_s"], d["collective_s"]) * 1e6
        out.append((f"roofline/{d['cell']}", step_us,
                    f"compute_s={d['compute_s']:.3e} "
                    f"memory_s={d['memory_s']:.3e} "
                    f"collective_s={d['collective_s']:.3e} "
                    f"bound={d['bound']} "
                    f"useful_frac={d['useful_flops_frac']:.2f} "
                    f"roofline_frac={d['roofline_frac']:.3f}"))
    if not out:
        out.append(("roofline/none", 0.0,
                    "run `python -m repro.launch.dryrun` first"))
    return out


def markdown_table(results_dir: str = RESULTS, mesh: str = "single",
                   tag: str = "") -> str:
    cells = load_cells(results_dir, mesh=mesh, tag=tag)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bound | "
        "MODEL/HLO flops | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in sorted(cells, key=lambda d: (d["arch"], d["shape"])):
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']:.3e} | "
            f"{d['memory_s']:.3e} | {d['collective_s']:.3e} | "
            f"{d['bound']} | {d['useful_flops_frac']:.2f} | "
            f"{d['roofline_frac']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
