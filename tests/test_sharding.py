"""Sharding rules + a small-mesh distributed compile in a subprocess."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.runtime import sharding as S
from repro.runtime import steps as ST


def _mesh22():
    """2x2 virtual mesh is only available in the subprocess tests; here we
    build specs against a fake mesh-like via the real 1-dev mesh."""
    return make_host_mesh((1, 1), ("data", "model"))


class TestParamRules:
    def test_attention_weights(self):
        mesh = _mesh22()
        spec = S.param_spec("layers.attn.wq.w", 3, mesh, S.BASELINE_RULES,
                            (4, 128, 128))
        # leading scan dim replicated; (fsdp, tp) on the matmul dims
        assert spec == P(None, "data", "model")

    def test_down_proj_transposed(self):
        mesh = _mesh22()
        spec = S.param_spec("layers.mlp.w_down.w", 3, mesh,
                            S.BASELINE_RULES, (4, 256, 128))
        assert spec == P(None, "model", "data")

    def test_embed(self):
        mesh = _mesh22()
        spec = S.param_spec("embed.table", 2, mesh, S.BASELINE_RULES,
                            (512, 128))
        assert spec == P("model", "data")

    def test_norm_replicated(self):
        mesh = _mesh22()
        spec = S.param_spec("layers.ln_attn.scale", 2, mesh,
                            S.BASELINE_RULES, (4, 128))
        assert all(a is None for a in spec)   # fully replicated

    def test_router_replicated(self):
        mesh = _mesh22()
        spec = S.param_spec("layers.moe.router.w", 3, mesh,
                            S.BASELINE_RULES, (4, 128, 60))
        assert spec == P(None, None, None)

    def test_divisibility_fallback(self):
        """vocab 50280 is not divisible by 16 -> that dim replicates."""
        mesh = make_host_mesh((1, 1), ("data", "model"))
        spec = S.param_spec("embed.table", 2, mesh, S.BASELINE_RULES,
                            (50281, 128))  # prime-ish, % 1 == 0 passes
        assert spec == P("model", "data")  # 1-way always divides

    def test_qtensor_scale_replicated(self):
        from repro.core.quant import quantize_weight
        mesh = _mesh22()
        q = quantize_weight(jnp.ones((128, 128)))
        sh = S.tree_shardings({"wq": {"w": q}}, mesh, S.BASELINE_RULES)
        assert sh["wq"]["w"].scale.spec == P()
        assert sh["wq"]["w"].values.spec == P("data", "model")


class TestCacheRules:
    def test_kv_cache(self):
        mesh = _mesh22()
        cache = {"k": jnp.zeros((4, 2, 64, 2, 8)),
                 "v": jnp.zeros((4, 2, 64, 2, 8))}
        sh = S.cache_shardings(cache, mesh, S.BASELINE_RULES)
        # (L, B, S, KV, hd): batch over dp, seq over sp(model)
        assert sh["k"].spec == P(None, "data", "model", None, None)

    def test_ssm_state(self):
        mesh = _mesh22()
        cache = {"h": jnp.zeros((4, 2, 8, 8, 16))}
        sh = S.cache_shardings(cache, mesh, S.BASELINE_RULES)
        assert sh["h"].spec == P(None, "data", "model", None, None)


class TestConstrainNoMesh:
    def test_noop_without_rules(self):
        x = jnp.ones((4, 4))
        assert S.constrain(x, "act") is x

    def test_applies_under_rules(self):
        mesh = make_host_mesh((1, 1), ("data", "model"))
        with S.use_rules(mesh, S.BASELINE_RULES):
            y = S.constrain(jnp.ones((4, 4, 4)), "act")
        assert y.shape == (4, 4, 4)


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.models import registry as R
    from repro.optim import make_optimizer
    from repro.runtime import sharding as S
    from repro.runtime import steps as ST

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("{arch}").reduced()
    key = jax.random.PRNGKey(0)
    with S.use_rules(mesh, S.BASELINE_RULES):
        params = jax.eval_shape(lambda k: R.init(k, cfg), key)
        opt = make_optimizer("adamw", lr=1e-3)
        opt_state = jax.eval_shape(opt.init, params)
        step = ST.make_train_step(cfg, opt, mesh=mesh,
                                  grad_compression={compression})
        p_sh = S.tree_shardings(params, mesh, S.BASELINE_RULES)
        o_sh = S.tree_shardings(opt_state, mesh, S.BASELINE_RULES)
        batch = {{"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                  "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}}
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, None, None),
                         out_shardings=(p_sh, o_sh, None))
        with mesh:
            compiled = jitted.lower(params, opt_state, batch, rng).compile()
    text = compiled.as_text()
    assert "all-reduce" in text or "all-gather" in text, "no collectives?"
    print("OK", len(text))
""")


@pytest.mark.parametrize("arch", ["starcoder2-3b", "qwen2-moe-a2.7b"])
def test_multipod_compile_subprocess(arch):
    """8 virtual devices (2 pod x 2 data x 2 model): the full train step
    lowers and compiles with the production sharding rules."""
    code = SUBPROC.format(arch=arch, compression="None")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


@pytest.mark.skipif(
    not ST.supports_int8_grad_exchange(),
    reason="XLA in JAX 0.4.x aborts on scan backward under partial-manual "
           "shard_map (IsManualSubgroup CHECK); exchange needs newer JAX")
def test_grad_compression_compiles_and_uses_int8_collectives():
    """int8 cross-pod gradient exchange: the compiled HLO must move the
    gradients over an s8 collective."""
    code = SUBPROC.format(arch="starcoder2-3b", compression="'int8'")
    code = code.replace(
        'print("OK", len(text))',
        'import re\n'
        'ag = re.findall(r"(?:all-gather|all-reduce)[^\\n]*s8\\[", text)\n'
        'print("OK", len(ag))\n'
        'assert ag, "no int8 collective found"')
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
