"""Overload robustness: SLO-class admission, preemption with exact
resume, fault injection + recovery, and the typed retirement statuses.

The correctness bar everywhere is the engine's usual one — bit-for-bit
parity with the sequential per-token reference — now required to hold
*through* evictions, re-admissions, and injected faults."""
import dataclasses
import warnings

import jax
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine as E
from repro.configs import get_config
from repro.core import batching as bt
from repro.engine.faults import FAULT_KINDS, Fault, FaultPlan
from repro.models import registry as R

KEY = jax.random.PRNGKey(0)


def _cfg():
    cfg = get_config("starcoder2-3b").reduced()
    return dataclasses.replace(cfg, kv_quant=True)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = _cfg()
    return cfg, R.init(KEY, cfg)


@pytest.fixture(scope="module")
def trace(dense_setup):
    """A short two-class trace plus its sequential reference outputs."""
    cfg, params = dense_setup
    reqs = E.synthetic_requests(
        10, rate_per_s=2000.0, vocab=cfg.vocab, prompt_len=3,
        max_new_tokens=5,
        priority=lambda rid: "batch" if rid % 3 == 0 else "interactive")
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)
    return reqs, want


# ---------------------------------------------------------------------------
# SLO-class admission
# ---------------------------------------------------------------------------

class TestClassAdmission:
    def test_scheduler_orders_class_first(self):
        def req(rid, deadline, cls):
            return E.EngineRequest(rid=rid, prompt=(1,), max_new_tokens=1,
                                   arrival_s=0.0, deadline_s=deadline,
                                   priority=cls)

        sched = E.SlotScheduler(bt.AdmissionPolicy(lambda b: 0.0,
                                                   max_batch=8))
        sched.push(req(0, 5.0, "batch"))
        sched.push(req(1, 9.0, "interactive"))
        sched.push(req(2, 1.0, "batch"))
        sched.push(req(3, 2.0, "interactive"))
        # interactive (rank 0) ahead of batch, deadline order within class
        assert [r.rid for r in sched.pending] == [3, 1, 2, 0]

    def test_quota_skips_over_blocked_class(self):
        policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=4,
                                    max_wait_s=0.0,
                                    class_quotas={"batch": 1})
        act = policy.decide(0.0, [1.0, 2.0, 3.0], capacity=3,
                            classes=["batch", "batch", "interactive"],
                            active_by_class={"batch": 1})
        # batch quota already consumed by an active slot: both pending
        # batch requests are skipped, the later interactive one admits
        assert act.launch and act.picks == (2,)

    def test_no_quota_no_classes_is_legacy_path(self):
        policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=4,
                                    max_wait_s=0.0)
        act = policy.decide(0.0, [1.0, 2.0], capacity=4)
        assert act.launch and act.batch == 2 and act.picks is None

    def test_unknown_class_ranks_last(self):
        assert bt.priority_rank("interactive") == 0
        assert bt.priority_rank("batch") == 1
        assert bt.priority_rank("mystery") == len(bt.PRIORITY_CLASSES)

    def test_quota_serve_parity(self, dense_setup, trace):
        """Quota-constrained admission reorders *when* requests run, but
        never what they produce."""
        cfg, params = dense_setup
        reqs, want = trace
        policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=4,
                                    max_wait_s=0.0,
                                    class_quotas={"batch": 1})
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, policy=policy)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
        assert rep.outputs() == want
        # batch never held more than its quota of slots at once
        assert all(r.status == "ok" for r in rep.results)


# ---------------------------------------------------------------------------
# preemption with exact resume
# ---------------------------------------------------------------------------

class TestPreemption:
    def test_block_pressure_preempts_and_resumes_exactly(
            self, dense_setup, trace):
        """A pool too small for the worst-case concurrent claim forces
        evictions; every resumed request is bit-for-bit its
        never-preempted self and the pool drains clean."""
        cfg, params = dense_setup
        reqs, want = trace
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, block_size=4, num_blocks=9)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True)
        assert rep.outputs() == want
        assert rep.preempted > 0
        assert rep.leaked_blocks == 0
        assert any(r.preemptions > 0 for r in rep.results)

    def test_uniform_class_never_preempts(self, dense_setup):
        """Preemption only evicts a *strictly* lower class than the
        waiting head: a single-class trace can never preempt, with the
        flag on and resources ample."""
        cfg, params = dense_setup
        reqs = E.synthetic_requests(10, rate_per_s=2000.0,
                                    vocab=cfg.vocab, prompt_len=3,
                                    max_new_tokens=5)
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, block_size=4)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True)
        assert rep.outputs() == want
        assert rep.preempted == 0 and rep.leaked_blocks == 0

    def test_sampled_resume_parity(self, dense_setup):
        """Position-derived sampling keys make resume exact for sampled
        decoding too, not just greedy."""
        cfg, params = dense_setup
        rng = jax.random.PRNGKey(7)
        reqs = E.synthetic_requests(
            8, rate_per_s=2000.0, vocab=cfg.vocab, prompt_len=3,
            max_new_tokens=4,
            priority=lambda rid: "batch" if rid % 2 else "interactive")
        want = E.reference_outputs(cfg, params, reqs, max_seq=16,
                                   temperature=0.8, rng=rng)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, block_size=4, num_blocks=9,
                       temperature=0.8, rng=rng)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True)
        assert rep.outputs() == want
        assert rep.preempted > 0 and rep.leaked_blocks == 0


# ---------------------------------------------------------------------------
# fault injection + recovery
# ---------------------------------------------------------------------------

class TestFaultInjection:
    def test_plan_is_deterministic_and_validated(self):
        a = FaultPlan.random(3, n_faults=6, num_slots=4)
        b = FaultPlan.random(3, n_faults=6, num_slots=4)
        assert a.faults == b.faults
        assert all(f.kind in FAULT_KINDS for f in a.faults)
        with pytest.raises(ValueError):
            Fault(tick=1, kind="meteor")
        with pytest.raises(ValueError):
            Fault(tick=-1, kind="dispatch")

    def test_transient_dispatch_fault_retries_to_parity(
            self, dense_setup, trace):
        cfg, params = dense_setup
        reqs, want = trace
        plan = FaultPlan([Fault(tick=4, kind="dispatch", slot=0,
                                repeat=2)])
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan)
        assert rep.outputs() == want
        assert rep.dispatch_retries == 2 and rep.failed == 0

    def test_persistent_dispatch_fault_fails_only_the_culprit(
            self, dense_setup, trace):
        cfg, params = dense_setup
        reqs, want = trace
        plan = FaultPlan([Fault(tick=4, kind="dispatch", slot=1,
                                repeat=99)])
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan,
                        max_retries=2)
        failed = [r for r in rep.results if r.status == "failed"]
        assert len(failed) == 1
        ok = {r.rid: r.tokens for r in rep.results if r.status == "ok"}
        assert all(ok[rid] == want[rid] for rid in ok)

    def test_nan_logits_recover_bitwise(self, dense_setup, trace):
        """A transient non-finite sample preempts the victim; the resume
        recomputes clean state and the output heals bit-for-bit."""
        cfg, params = dense_setup
        reqs, want = trace
        plan = FaultPlan([Fault(tick=5, kind="nan_logits", slot=2)])
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan)
        assert rep.outputs() == want
        assert rep.nonfinite_samples >= 1 and rep.failed == 0

    def test_torn_table_row_repaired_from_host_mirror(
            self, dense_setup, trace):
        cfg, params = dense_setup
        reqs, want = trace
        plan = FaultPlan([Fault(tick=5, kind="torn_table", slot=0)])
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, block_size=4)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan)
        assert rep.outputs() == want
        assert rep.torn_rows_repaired >= 1
        assert rep.leaked_blocks == 0


# ---------------------------------------------------------------------------
# typed retirement statuses + scheduler guards
# ---------------------------------------------------------------------------

class TestTypedStatuses:
    def test_tick_cap_retires_unfinished_with_warning(
            self, dense_setup, trace):
        cfg, params = dense_setup
        reqs, _ = trace
        eng = E.Engine(cfg, params, num_slots=2, max_seq=16)
        with pytest.warns(RuntimeWarning, match="tick cap"):
            rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                            max_ticks=6)
        # nothing lost, nothing silently reported as served
        assert len(rep.results) == len(reqs)
        assert rep.unfinished > 0
        assert {r.status for r in rep.results} <= {"ok", "unfinished"}
        assert sum(r.status == "unfinished" for r in rep.results) == \
            rep.unfinished

    def test_every_request_retires_exactly_once(self, dense_setup, trace):
        cfg, params = dense_setup
        reqs, _ = trace
        plan = FaultPlan.random(5, n_faults=6, max_tick=60, num_slots=4)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, block_size=4, num_blocks=9)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan)
        assert sorted(r.rid for r in rep.results) == \
            sorted(r.rid for r in reqs)

    def test_run_virtual_guards_stalled_policy(self):
        """A policy that declines a non-empty queue after the last
        arrival must surface as a clear error, not a None TypeError."""
        class Never(bt.AdmissionPolicy):
            def decide(self, *a, **k):
                return bt.Admission(False, wait_until=None)

        sched = E.SlotScheduler(Never(lambda b: 0.0, max_batch=4))
        reqs = [bt.Request(0.0, 1.0, 0)]
        with pytest.raises(RuntimeError, match="declined"):
            sched.run_virtual(reqs)


# ---------------------------------------------------------------------------
# per-class metrics + goodput
# ---------------------------------------------------------------------------

def test_per_class_metrics_and_goodput(dense_setup, trace):
    cfg, params = dense_setup
    reqs, _ = trace
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=2)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert set(rep.class_p99_latency_s) == {"interactive", "batch"}
    assert set(rep.class_mean_ttft_s) == {"interactive", "batch"}
    assert set(rep.class_p99_ttft_s) == {"interactive", "batch"}
    assert all(v > 0 for v in rep.class_p99_latency_s.values())
    # synthetic deadlines are infinite: everything is goodput
    assert rep.slo_attainment == 1.0
    assert rep.goodput_tokens_per_s == pytest.approx(rep.tokens_per_s)


# ---------------------------------------------------------------------------
# preemption storm: the property test
# ---------------------------------------------------------------------------

_STORM = {}


def _storm_setup():
    """Module-cached engine + trace + reference for the property test
    (the hypothesis shim's @given cannot consume pytest fixtures)."""
    if not _STORM:
        cfg = _cfg()
        params = R.init(KEY, cfg)
        reqs = E.synthetic_requests(
            12, rate_per_s=4000.0, vocab=cfg.vocab, prompt_len=3,
            max_new_tokens=4,
            priority=lambda rid: "batch" if rid % 2 else "interactive")
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=2, block_size=4, num_blocks=9)
        _STORM["setup"] = (eng, reqs, want)
    return _STORM["setup"]


@given(st.integers(0, 1000))
@settings(max_examples=5, deadline=None)
def test_preemption_storm_property(seed):
    """Random fault plans over an under-provisioned pool: refcounts stay
    non-negative (BlockPool raises internally otherwise), the pool
    drains to its initial free count (no leaks), and every non-failed
    output is bit-for-bit the reference."""
    eng, reqs, want = _storm_setup()
    plan = FaultPlan.random(seed, n_faults=8, max_tick=120, num_slots=4)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan)
    assert rep.leaked_blocks == 0
    assert sorted(r.rid for r in rep.results) == [r.rid for r in reqs]
    for r in rep.results:
        if r.status == "ok":
            assert r.tokens == want[r.rid], \
                f"rid {r.rid} diverged under fault seed {seed}"
