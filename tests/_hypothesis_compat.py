"""Offline fallback for ``hypothesis`` (not installed, no network).

Test modules import via::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

With real hypothesis installed the property tests run unchanged; offline
they degrade to example-based tests over a bounded, deterministic grid of
examples drawn from each strategy (endpoints + evenly spread interior
points), so the properties still execute with meaningful coverage.

Only the strategy surface this repo uses is implemented: ``floats``,
``integers``, ``sampled_from``, ``booleans``, ``just``, plus ``.filter``
and ``.map``.
"""
from __future__ import annotations

import functools
import inspect
import itertools
from typing import Any, Callable, List

_MAX_EXAMPLES_DEFAULT = 20


class _Strategy:
    """A bounded, deterministic pool of example values."""

    def __init__(self, examples: List[Any]):
        self._examples = list(examples)

    def examples(self) -> List[Any]:
        return self._examples

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        return _Strategy([x for x in self._examples if pred(x)])

    def map(self, fn: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy([fn(x) for x in self._examples])


def _spread(lo: float, hi: float, n: int, cast) -> List[Any]:
    """Endpoints plus evenly spaced interior points, deduplicated."""
    if n <= 1:
        return [cast(lo)]
    vals = [cast(lo + (hi - lo) * i / (n - 1)) for i in range(n)]
    out: List[Any] = []
    for v in vals:
        if v not in out and lo <= v <= hi:
            out.append(v)
    return out


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0,
               **_kw) -> _Strategy:
        return _Strategy(_spread(float(min_value), float(max_value), 7,
                                 float))

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 100,
                 **_kw) -> _Strategy:
        return _Strategy(_spread(int(min_value), int(max_value), 7,
                                 lambda v: int(round(v))))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        return _Strategy(list(elements))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy([False, True])

    @staticmethod
    def just(value) -> _Strategy:
        return _Strategy([value])


def settings(max_examples: int = _MAX_EXAMPLES_DEFAULT, **_kw):
    """Records max_examples on the test for @given to consume."""
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy, **kw_strats: _Strategy):
    """Run the test over a deterministic cross-product of examples,
    round-robin truncated to max_examples (mirrors the hypothesis API
    closely enough for this repo's positional usage)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            inner = fn
            max_examples = getattr(fn, "_compat_max_examples",
                                   _MAX_EXAMPLES_DEFAULT)
            pools = [s.examples() for s in strats]
            kw_names = list(kw_strats)
            pools += [kw_strats[k].examples() for k in kw_names]
            if any(not p for p in pools):
                raise ValueError("strategy produced no examples "
                                 "(over-restrictive filter?)")
            combos = list(itertools.islice(itertools.product(*pools),
                                           10 * max_examples))
            # spread selection across the product, not just its prefix
            stride = max(1, len(combos) // max_examples)
            for combo in combos[::stride][:max_examples]:
                pos = combo[:len(strats)]
                kws = dict(zip(kw_names, combo[len(strats):]))
                inner(*args, *pos, **kws, **kwargs)
        # keep pytest from collecting strategy args as fixtures
        sig = inspect.signature(fn)
        keep = list(sig.parameters.values())
        n_drop = len(strats) + len(kw_strats)
        has_self = keep and keep[0].name == "self"
        base = keep[:1] if has_self else []
        wrapper.__signature__ = sig.replace(parameters=base)
        wrapper.hypothesis_compat = True
        return wrapper
    return deco


# `from _hypothesis_compat import strategies as st` usage
st = strategies
