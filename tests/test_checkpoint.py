"""Checkpoint fault-tolerance: commit protocol, integrity, resume."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"step": jnp.array(3), "m": jnp.ones((8, 16))}}


class TestRoundTrip:
    def test_save_restore_identical(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 10, t)
        out = restore_checkpoint(str(tmp_path), 10, t)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_metadata(self, tmp_path):
        from repro.checkpoint.manager import read_metadata
        save_checkpoint(str(tmp_path), 5, _tree(), {"data_step": 5})
        assert read_metadata(str(tmp_path), 5)["data_step"] == 5


class TestCommitProtocol:
    def test_uncommitted_ignored(self, tmp_path):
        """A save that died before the marker must be invisible."""
        path = save_checkpoint(str(tmp_path), 7, _tree())
        os.remove(os.path.join(path, "COMMITTED"))
        assert latest_step(str(tmp_path)) is None

    def test_tmp_dirs_ignored(self, tmp_path):
        os.makedirs(tmp_path / "step_0000000009.tmp")
        save_checkpoint(str(tmp_path), 4, _tree())
        assert latest_step(str(tmp_path)) == 4

    def test_digest_mismatch_raises(self, tmp_path):
        t = _tree()
        path = save_checkpoint(str(tmp_path), 3, t)
        # corrupt one array on disk
        victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(path, victim))
        np.save(os.path.join(path, victim), arr + 1)
        with pytest.raises(IOError, match="digest"):
            restore_checkpoint(str(tmp_path), 3, t)

    def test_latest_picks_newest_committed(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, _tree())
        save_checkpoint(str(tmp_path), 2, _tree())
        p3 = save_checkpoint(str(tmp_path), 3, _tree())
        os.remove(os.path.join(p3, "COMMITTED"))   # partial newest
        assert latest_step(str(tmp_path)) == 2


class TestManager:
    def test_async_save_and_restore(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        t = _tree()
        mgr.save_async(1, t)
        mgr.wait()
        step, out = mgr.restore_latest(t)
        assert step == 1
        np.testing.assert_array_equal(
            np.asarray(out["params"]["w"]), np.asarray(t["params"]["w"]))

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(s, _tree(s))
            mgr.wait()
        steps = sorted(int(d[5:]) for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == [3, 4]

    def test_restore_none_when_empty(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(_tree()) == (None, None)


def test_elastic_restore_under_mesh(tmp_path):
    """Checkpoints are logical: restore places arrays into whatever mesh
    sharding is active (re-mesh on restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    t = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    save_checkpoint(str(tmp_path), 1, t)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P(None, None))}
    out = restore_checkpoint(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
    assert out["w"].sharding == sh["w"]
