"""Dispatch core + executor backends: backend-interface conformance
(both executors serve the same trace bit-for-bit through the same
DispatchCore), model hot-swap on a live engine (admit/retire with
drain + typed refusal), per-lane tick pricing, and the named watchdog.

The sharded executor runs here at tp=1 (a 1-device mesh), which pins
the interface and the shard_map plumbing in-process; the real
multi-device parity gates live in tests/test_sharded.py behind a
forced multi-device CPU mesh in a subprocess."""
import dataclasses

import jax
import pytest

from repro import engine as E
from repro.configs import get_config
from repro.models import registry as R
from repro.runtime import steps as ST
from repro.runtime.watchdog import StepWatchdog

KEY = jax.random.PRNGKey(0)


def _cfg(arch="starcoder2-3b", seed=0):
    cfg = dataclasses.replace(get_config(arch).reduced(), kv_quant=True)
    return cfg, R.init(jax.random.PRNGKey(seed), cfg)


@pytest.fixture(scope="module")
def dense_setup():
    return _cfg("starcoder2-3b", 0)


@pytest.fixture(scope="module")
def moe_setup():
    return _cfg("qwen2-moe-a2.7b", 1)


def _trace(tag, cfg, n, *, seed, rid_offset=0, shift=0.0):
    reqs = E.synthetic_requests(n, rate_per_s=2000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=5, seed=seed,
                                model=tag)
    return [dataclasses.replace(r, rid=r.rid + rid_offset,
                                arrival_s=r.arrival_s + shift)
            for r in reqs]


# ---------------------------------------------------------------------------
# backend interface conformance
# ---------------------------------------------------------------------------

def test_abstract_backend_provides_no_steps(dense_setup):
    """The base ExecutorBackend is an interface: every step provider
    must raise, and validate() must accept anything (it is the hook,
    not a gate, at this level)."""
    cfg, _ = dense_setup
    from repro.core.qlinear import W8A16
    b = E.ExecutorBackend()
    assert b.kind == "abstract" and b.tp == 1
    b.validate(object())               # no-op on the base class
    with pytest.raises(NotImplementedError):
        b.slot_step(cfg, mode=W8A16, temperature=0.0)
    with pytest.raises(NotImplementedError):
        b.chunk_step(cfg, mode=W8A16, chunk=4)
    with pytest.raises(NotImplementedError):
        b.prime_step(cfg, mode=W8A16)
    with pytest.raises(NotImplementedError):
        b.verify_step(cfg, mode=W8A16, k=2, temperature=0.0)
    with pytest.raises(NotImplementedError):
        b.propose_step(cfg, mode=W8A16, k=2)


def test_sharded_executor_rejects_bad_tp():
    if not ST.supports_sharded_serving():
        pytest.skip("no shard_map in this jax")
    with pytest.raises(ValueError, match="tp must be >= 1"):
        E.ShardedExecutor(tp=0)
    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="exceeds"):
        E.ShardedExecutor(tp=ndev + 1)


def test_sharded_executor_validates_slot_divisibility(dense_setup):
    """A slot pool that does not divide across the mesh is rejected at
    Engine construction, before any step compiles."""
    if not ST.supports_sharded_serving():
        pytest.skip("no shard_map in this jax")
    cfg, params = dense_setup
    b = E.ShardedExecutor(tp=1)
    b.tp = 3                           # a mesh width 4 slots can't fill
    with pytest.raises(ValueError, match="must divide"):
        E.Engine(cfg, params, num_slots=4, max_seq=16, backend=b)


def test_backends_are_bitwise_interchangeable(dense_setup):
    """The conformance gate: the same engine shape served through the
    SingleDeviceExecutor and through a ShardedExecutor(tp=1) produces
    bit-identical outputs and identical accounting — the backend seam
    carries steps, not behavior."""
    if not ST.supports_sharded_serving():
        pytest.skip("no shard_map in this jax")
    cfg, params = dense_setup
    reqs = E.synthetic_requests(16, rate_per_s=2000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=5)
    kw = dict(num_slots=4, max_seq=16, prefill_chunk=2, block_size=4)
    single = E.Engine(cfg, params, backend=E.SingleDeviceExecutor(), **kw)
    sharded = E.Engine(cfg, params, backend=E.ShardedExecutor(tp=1), **kw)
    assert single.backend.kind == "single"
    assert sharded.backend.kind == "sharded" and sharded.backend.tp == 1
    r1 = single.serve(reqs, tick_s=1e-3)
    r2 = sharded.serve(reqs, tick_s=1e-3)
    assert r1.outputs() == r2.outputs()
    assert r1.ticks == r2.ticks
    assert r1.leaked_blocks == r2.leaked_blocks == 0
    assert r1.outputs() == E.reference_outputs(cfg, params, reqs,
                                               max_seq=16)


def test_default_backend_is_single_device(dense_setup):
    cfg, params = dense_setup
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16)
    assert isinstance(eng.backend, E.SingleDeviceExecutor)


# ---------------------------------------------------------------------------
# model hot-swap on a live engine
# ---------------------------------------------------------------------------

def test_retire_model_drains_inflight_and_refuses_late(dense_setup,
                                                       moe_setup):
    """retire_model mid-serve: in-flight requests on the retiring lane
    drain to completion with bit-identical outputs, later arrivals for
    that lane get a typed ``refused`` result, the drained lane is
    removed post-serve, and the surviving lane is undisturbed."""
    cfg_a, pa = dense_setup
    cfg_b, pb = moe_setup
    ta = _trace("a", cfg_a, 12, seed=11)
    tb = _trace("b", cfg_b, 12, seed=22, rid_offset=100)
    tb_late = _trace("b", cfg_b, 6, seed=33, rid_offset=200, shift=0.004)
    merged = sorted(ta + tb + tb_late, key=lambda r: r.arrival_s)

    def build():
        return E.Engine(models={"a": (cfg_a, pa), "b": (cfg_b, pb)},
                        num_slots=4, max_seq=16, prefill_chunk=2)

    eng = build()
    rep = eng.serve(merged, tick_s=1e-3,
                    control=[(0.004, lambda e: e.retire_model("b"))])
    assert len(rep.results) == len(merged)     # nothing lost
    ok_b = [r for r in rep.results if r.model == "b" and r.status == "ok"]
    ref_b = [r for r in rep.results
             if r.model == "b" and r.status == "refused"]
    assert ok_b and ref_b
    assert rep.refused == len(ref_b)
    assert all(r.tokens == [] and r.slot == -1 for r in ref_b)
    # the drained lane is gone; the survivor is not
    assert "b" not in eng.lanes and "a" in eng.lanes

    # same trace, no retire: the in-flight b outputs and all of lane a
    # must be bitwise what the control run produced
    base = build().serve(merged, tick_s=1e-3).outputs()
    assert all(base[r.rid] == r.tokens for r in ok_b)
    assert {r.rid: r.tokens for r in rep.results if r.model == "a"} == \
        {r.rid: base[r.rid] for r in ta}


def test_admit_model_joins_live_serve(dense_setup, moe_setup):
    """admit_model mid-serve: a lane admitted by a control op serves
    requests that arrived addressed to it, and its outputs are
    bit-identical to a dedicated engine over the same sub-trace."""
    cfg_a, pa = dense_setup
    cfg_b, pb = moe_setup
    ta = _trace("a", cfg_a, 12, seed=11)
    tc = _trace("c", cfg_b, 6, seed=44, rid_offset=300, shift=0.003)
    merged = sorted(ta + tc, key=lambda r: r.arrival_s)
    eng = E.Engine(models={"a": (cfg_a, pa)}, num_slots=4, max_seq=16,
                   prefill_chunk=2)
    rep = eng.serve(merged, tick_s=1e-3,
                    control=[(0.002,
                              lambda e: e.admit_model("c", cfg_b, pb))])
    okc = [r for r in rep.results if r.model == "c" and r.status == "ok"]
    assert len(okc) == len(tc)
    assert "c" in eng.lanes             # admitted lanes persist
    ded = E.Engine(cfg_b, pb, num_slots=4, max_seq=16, prefill_chunk=2)
    want = ded.serve([dataclasses.replace(r, model=None) for r in tc],
                     tick_s=1e-3).outputs()
    assert {r.rid: r.tokens for r in okc} == want


def test_admit_model_rejects_duplicates_and_single_model(dense_setup,
                                                         moe_setup):
    cfg_a, pa = dense_setup
    cfg_b, pb = moe_setup
    eng = E.Engine(models={"a": (cfg_a, pa)}, num_slots=2, max_seq=16)
    with pytest.raises(ValueError, match="already"):
        eng.admit_model("a", cfg_a, pa)
    single = E.Engine(cfg_a, pa, num_slots=2, max_seq=16)
    with pytest.raises(ValueError):
        single.admit_model("b", cfg_b, pb)


# ---------------------------------------------------------------------------
# per-lane tick pricing
# ---------------------------------------------------------------------------

def test_per_lane_tick_cost_prices_dispatched_lanes(dense_setup,
                                                    moe_setup):
    """A Mapping tick_s prices each tick as the sum of the DISPATCHED
    lanes' costs: outputs are untouched (pricing is pure accounting)
    but the expensive lane stretches the clock."""
    cfg_a, pa = dense_setup
    cfg_b, pb = moe_setup
    merged = sorted(_trace("a", cfg_a, 12, seed=11)
                    + _trace("b", cfg_b, 12, seed=22, rid_offset=100),
                    key=lambda r: r.arrival_s)

    def build():
        return E.Engine(models={"a": (cfg_a, pa), "b": (cfg_b, pb)},
                        num_slots=4, max_seq=16, prefill_chunk=2)

    priced = build().serve(merged, tick_s={"a": 1e-3, "b": 5e-3})
    flat = build().serve(merged, tick_s=1e-3)
    assert priced.outputs() == flat.outputs()
    assert priced.duration_s > flat.duration_s


def test_per_lane_tick_cost_validation(dense_setup, moe_setup):
    cfg_a, pa = dense_setup
    cfg_b, pb = moe_setup
    eng = E.Engine(models={"a": (cfg_a, pa), "b": (cfg_b, pb)},
                   num_slots=2, max_seq=16)
    reqs = _trace("a", cfg_a, 2, seed=1)
    with pytest.raises(ValueError, match="virtual"):
        eng.serve(reqs, clock="wall", tick_s={"a": 1e-3, "b": 1e-3})
    with pytest.raises(ValueError, match="every lane"):
        eng.serve(reqs, tick_s={"a": 1e-3})   # lane b unpriced


# ---------------------------------------------------------------------------
# named watchdog
# ---------------------------------------------------------------------------

def test_watchdog_name_labels_stragglers():
    """A named watchdog prefixes straggler warnings with its replica
    label; an anonymous one keeps the legacy message."""
    def provoke(wd):
        for _ in range(wd.warmup_steps):
            wd.record(1e-3)
        for _ in range(8):
            wd.record(1e-3)
        return wd.record(1.0)
    named = provoke(StepWatchdog(name="replica3"))
    assert named is not None and named.startswith("[replica3] straggler")
    anon = provoke(StepWatchdog())
    assert anon is not None and anon.startswith("straggler")


def test_engine_name_reaches_watchdog(dense_setup):
    cfg, params = dense_setup
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16, name="r0")
    assert eng.name == "r0"
