"""Regenerate the golden HLO fixtures from the installed JAX/XLA.

Run from the repo root:  PYTHONPATH=src python tests/fixtures/hlo/regen.py

The fixtures pin the *text shape* of post-SPMD HLO that
``repro.core.hlo_cost`` must parse (scan, nested scan, fusion-with-dot,
psum, donated dynamic-update-slice).  The expected cost numbers asserted in
``tests/test_hlo_cost.py`` are functions of the program, not the XLA
version, so regenerated fixtures must keep passing the same assertions.

The psum fixture needs 4 devices, so this script re-executes itself in a
subprocess with XLA_FLAGS set before jax is imported.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))


def _write(name, text):
    with open(os.path.join(HERE, name), "w") as f:
        f.write(text)
    print(f"wrote {name}: {len(text)} bytes")


def main():
    import jax
    import jax.numpy as jnp

    def compiled(f, *specs, **jit_kw):
        return jax.jit(f, **jit_kw).lower(*specs).compile()

    # scan of (64,128)@(128,128) over 8 layers -> 2*64*128*128*8 flops
    def scan_f(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]
    _write("scan_matmul.hlo", compiled(
        scan_f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)).as_text())

    # nested scan: inner length=3 over outer 8 -> 24 matmuls
    def nested_f(x, w):
        def outer(x, wi):
            def inner(x, _):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(inner, x, None, length=3)[0], None
        return jax.lax.scan(outer, x, w)[0]
    _write("nested_scan.hlo", compiled(
        nested_f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)).as_text())

    # fusion with dot: matmul + bias + gelu fuses the pointwise tail
    def fused_f(a, b, c):
        return jax.nn.gelu(a @ b + c)
    _write("fusion_dot.hlo", compiled(
        fused_f, jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 16), jnp.float32),
        jax.ShapeDtypeStruct((16,), jnp.float32)).as_text())

    # donated KV-cache style dynamic-update-slice
    def dus_f(cache, new):
        return jax.lax.dynamic_update_slice(cache, new, (0, 5, 0))
    _write("dus_donated.hlo", compiled(
        dus_f, jax.ShapeDtypeStruct((4, 1024, 64), jnp.float32),
        jax.ShapeDtypeStruct((4, 1, 64), jnp.float32),
        donate_argnums=(0,)).as_text())


def psum_main():
    from functools import partial

    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((4,), ("x",))
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    sa = NamedSharding(mesh, P(None, "x"))
    sb = NamedSharding(mesh, P("x", None))
    with mesh:
        c = jax.jit(lambda a, b: a @ b, in_shardings=(sa, sb),
                    out_shardings=NamedSharding(mesh, P())) \
            .lower(a, b).compile()
    _write("psum.hlo", c.as_text())

    # all-reduce INSIDE a scanned while: collective bytes/counts must be
    # multiplied by the 8-iteration trip count.
    def scan_psum(x, w):
        @partial(shard_map, mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
                 out_specs=P(None, None))
        def mm(xs, ws):
            return jax.lax.psum(xs @ ws, "x")
        return jax.lax.scan(lambda c, wi: (mm(c, wi), None), x, w)[0]
    c = jax.jit(scan_psum).lower(
        jax.ShapeDtypeStruct((16, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)).compile()
    _write("scan_psum.hlo", c.as_text())


if __name__ == "__main__":
    if "--psum" in sys.argv:
        psum_main()
    else:
        main()
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        subprocess.run([sys.executable, __file__, "--psum"], env=env,
                       check=True)
