"""End-to-end behaviour tests: the paper's full story on a reduced system.

train (fp) -> post-training int8 quantization -> latency-bounded batched
serving with the Table 4 scheduler — the complete TPU workflow, on CPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import batching as bt
from repro.core.qlinear import W8A16
from repro.core.quant import quantize_tree, tree_weight_bytes
from repro.data import SyntheticLMData
from repro.models import registry as R
from repro.optim import make_optimizer
from repro.runtime import steps as ST

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def trained_model():
    cfg = get_config("starcoder2-3b").reduced()
    params = R.init(KEY, cfg)
    opt = make_optimizer("adamw", lr=3e-3)
    state = opt.init(params)
    step = jax.jit(ST.make_train_step(cfg, opt), donate_argnums=(0, 1))
    data = SyntheticLMData(cfg.vocab, 32, 8, seed=0)
    losses = []
    for t in range(25):
        tokens, labels = data.batch_at(t)
        batch = {"tokens": jnp.asarray(tokens),
                 "labels": jnp.asarray(labels)}
        params, state, m = step(params, state, batch,
                                jax.random.fold_in(KEY, t))
        losses.append(float(m["loss"]))
    return cfg, params, losses


def test_training_learns(trained_model):
    _, _, losses = trained_model
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_quantization_shrinks_weights(trained_model):
    cfg, params, _ = trained_model
    q = quantize_tree(params, min_size=2048)
    assert tree_weight_bytes(q) < 0.5 * tree_weight_bytes(params)


def test_quantized_model_quality(trained_model):
    """int8 serving path: next-token agreement with the fp model."""
    cfg, params, _ = trained_model
    q = quantize_tree(params, min_size=2048)
    data = SyntheticLMData(cfg.vocab, 32, 8, seed=99)
    tokens, _ = data.batch_at(0)
    batch = {"tokens": jnp.asarray(tokens)}
    fp = R.apply_forward(params, cfg, batch)
    qi = R.apply_forward(q, cfg, batch, mode=W8A16)
    agree = float(jnp.mean((jnp.argmax(fp, -1) == jnp.argmax(qi, -1))))
    assert agree > 0.9, f"top-1 agreement {agree}"


def test_generate_tokens(trained_model):
    """Autoregressive generation through the decode path is coherent."""
    cfg, params, _ = trained_model
    decode = jax.jit(ST.make_decode_step(cfg))
    cache = R.init_cache(cfg, 2, 32)
    tok = jnp.array([[1], [2]], jnp.int32)
    toks = [tok]
    for i in range(8):
        logits, cache = decode(params,
                               {"tokens": tok,
                                "cache_index": jnp.array(i)}, cache)
        tok = ST.greedy_sample(logits)[:, None]
        toks.append(tok)
    out = jnp.concatenate(toks, axis=1)
    assert out.shape == (2, 9)
    assert int(out.max()) < cfg.vocab


def test_latency_bounded_serving(trained_model):
    """Serve the quantized model through the BatchQueue under a deadline,
    with the service-time model measured from the actual jit step."""
    import time
    cfg, params, _ = trained_model
    q = quantize_tree(params, min_size=2048)
    prefill = jax.jit(ST.make_prefill_step(cfg, mode=W8A16))
    data = SyntheticLMData(cfg.vocab, 32, 16, seed=5)
    tokens, _ = data.batch_at(0)

    def run(b):
        batch = {"tokens": jnp.asarray(tokens[:b])}
        prefill(q, batch).block_until_ready()   # compile
        t0 = time.perf_counter()
        for _ in range(3):
            prefill(q, batch).block_until_ready()
        return (time.perf_counter() - t0) / 3

    t4, t16 = run(4), run(16)
    per_item = max((t16 - t4) / 12, 1e-6)
    fixed = max(t4 - 4 * per_item, 1e-6)
    model = bt.LatencyModel("local", fixed * 2, per_item * 2, fixed,
                            per_item)
    deadline = model.p99_latency(8)   # achievable deadline
    b = bt.choose_batch(model, deadline, max_batch=16)
    assert 1 <= b <= 16
    reqs = bt.poisson_arrivals(rate_per_s=4 / model.service_time(1),
                               n=40, deadline_s=deadline)
    recs = bt.BatchQueue(model.service_time, max_batch=b).run(reqs)
    served = sorted(r for rec in recs for r in rec.rids)
    assert served == list(range(40))
