"""Sharded executor parity: the tensor-parallel backend must be
BIT-FOR-BIT the single-device engine on 200-request traces, per decode
family, greedy AND sampled, plus paged + preemption under block
pressure.

XLA only honors ``--xla_force_host_platform_device_count`` before the
first jax import, so the 4-way CPU mesh runs in a subprocess (same
discipline as tests/test_bench_smoke.py); the in-process tp=1
conformance gate lives in tests/test_dispatch.py.  Every parity check
compares the sharded engine against the single-device engine serving
the SAME trace in the SAME process — the strictest comparison: any
reassociated float add, lost slot write, or mis-merged paged block
flips a bit.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                           + os.environ.get("XLA_FLAGS", ""))
import dataclasses, json, sys
import jax

from repro import engine as E
from repro.configs import get_config
from repro.models import registry as R
from repro.runtime import steps as ST

TP = 4
out = {"devices": len(jax.devices()),
       "supported": ST.supports_sharded_serving(), "checks": {}}
if out["devices"] < TP or not out["supported"]:
    print("RESULT " + json.dumps(out))
    sys.exit(0)


def parity(name, cfg, params, reqs, engine_kw, serve_kw):
    single = E.Engine(cfg, params, **engine_kw)
    sharded = E.Engine(cfg, params, backend=E.ShardedExecutor(tp=TP),
                       **engine_kw)
    r1 = single.serve(reqs, tick_s=1e-3, **serve_kw)
    r2 = sharded.serve(reqs, tick_s=1e-3, **serve_kw)
    out["checks"][name] = {
        "n": len(reqs),
        "results": len(r1.results),
        "bit_identical": r1.outputs() == r2.outputs(),
        "same_result_count": len(r1.results) == len(r2.results),
        "generated_tokens": r1.generated_tokens,
        "tokens_match": r1.generated_tokens == r2.generated_tokens,
        "preempted": (r1.preempted, r2.preempted),
        "leaked_blocks": (r1.leaked_blocks, r2.leaked_blocks),
    }


FAMILIES = [
    ("dense", "starcoder2-3b", True),
    ("moe", "qwen2-moe-a2.7b", True),
    ("encdec", "whisper-medium", False),
]
for fam, arch, kvq in FAMILIES:
    cfg = get_config(arch).reduced()
    if kvq:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = R.init(jax.random.PRNGKey(0), cfg)
    src = R.source_shape(cfg)
    reqs = E.synthetic_requests(200, rate_per_s=2000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=5,
                                source_shape=src)
    kw = dict(num_slots=8, max_seq=16)
    parity(fam + "/greedy", cfg, params, reqs, kw, {})
    parity(fam + "/sampled", cfg, params, reqs,
           dict(kw, temperature=0.8, rng=jax.random.PRNGKey(7)), {})

# paged + preemption + sampled under block pressure (the
# tests/test_robustness.py recipe, scaled to the 200-request trace):
# stash/exact-resume must survive the shard merge bit-for-bit
cfg = dataclasses.replace(get_config("starcoder2-3b").reduced(),
                          kv_quant=True)
params = R.init(jax.random.PRNGKey(0), cfg)
reqs = E.synthetic_requests(
    200, rate_per_s=2000.0, vocab=cfg.vocab, prompt_len=3,
    max_new_tokens=4,
    priority=lambda rid: "batch" if rid % 2 else "interactive")
parity("dense/paged_preempt_sampled", cfg, params, reqs,
       dict(num_slots=4, max_seq=16, prefill_chunk=2, block_size=4,
            num_blocks=9, temperature=0.8, rng=jax.random.PRNGKey(7)),
       dict(preemption=True))

# chunked + paged greedy (block-table decode through the shard merge)
reqs = E.synthetic_requests(200, rate_per_s=2000.0, vocab=cfg.vocab,
                            prompt_len=6, max_new_tokens=5,
                            shared_prefix_len=4)
parity("dense/paged_chunked", cfg, params, reqs,
       dict(num_slots=8, max_seq=16, prefill_chunk=4, block_size=4), {})

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def shard_doc(tmp_path_factory):
    """Run every parity check once, in one subprocess (one jax import,
    one compile set), and hand the JSON record to the tests."""
    tmp = tmp_path_factory.mktemp("sharded")
    script = tmp / "worker.py"
    script.write_text(WORKER)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("REPRO_AUTOTUNE_CACHE", str(tmp / "autotune.json"))
    proc = subprocess.run([sys.executable, str(script)], cwd=REPO,
                          env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, (
        f"sharded parity worker failed\nstdout:\n{proc.stdout}\n"
        f"stderr:\n{proc.stderr[-4000:]}")
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    doc = json.loads(line[len("RESULT "):])
    if doc["devices"] < 4 or not doc["supported"]:
        pytest.skip(f"no 4-way host mesh here ({doc})")
    return doc


ALL_CHECKS = ["dense/greedy", "dense/sampled", "moe/greedy",
              "moe/sampled", "encdec/greedy", "encdec/sampled",
              "dense/paged_preempt_sampled", "dense/paged_chunked"]


def test_all_parity_checks_ran(shard_doc):
    assert sorted(shard_doc["checks"]) == sorted(ALL_CHECKS)
    for name, c in shard_doc["checks"].items():
        assert c["n"] == 200, name
        assert c["results"] == 200, (name, c)


@pytest.mark.parametrize("name", ALL_CHECKS)
def test_sharded_is_bit_identical(shard_doc, name):
    c = shard_doc["checks"][name]
    assert c["bit_identical"], (
        f"{name}: sharded outputs diverge from single-device "
        f"({c})")
    assert c["same_result_count"] and c["tokens_match"], (name, c)


def test_preemption_fired_and_matched(shard_doc):
    """The paged-pressure arm must actually preempt (otherwise the
    stash/resume path was never sharded) and both backends must count
    the SAME preemptions — scheduling is host-side and backend-blind."""
    p1, p2 = shard_doc["checks"]["dense/paged_preempt_sampled"]["preempted"]
    assert p1 > 0 and p1 == p2
    for name in ("dense/paged_preempt_sampled", "dense/paged_chunked"):
        l1, l2 = shard_doc["checks"][name]["leaked_blocks"]
        assert l1 == 0 and l2 == 0, (name, l1, l2)
