"""Fused int8-KV decode-attention kernel: interpret-mode parity vs the
dense jnp oracle, plus structural properties (masking, scale folding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


def _case(key, b, s, kv, g, hd, scale_lo=0.005, scale_hi=0.05):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, kv, g, hd), jnp.float32)
    k = jax.random.randint(ks[1], (b, s, kv, hd), -127, 127, jnp.int8)
    v = jax.random.randint(ks[2], (b, s, kv, hd), -127, 127, jnp.int8)
    kscale = jax.random.uniform(ks[3], (b, s, kv, 1), jnp.float32,
                                scale_lo, scale_hi)
    vscale = jax.random.uniform(ks[4], (b, s, kv, 1), jnp.float32,
                                scale_lo, scale_hi)
    return q, k, v, kscale, vscale


# (B, S, KV, G, hd, valid_len): small-M GQA decode shapes — ragged head
# groups / head dims exercise the wrapper's padding, S=384 the multi-block
# online-softmax sweep, valid_len=1 the nearly-empty cache.
CASES = [
    (1, 128, 1, 1, 64, 37),
    (2, 256, 2, 4, 128, 256),
    (1, 128, 2, 3, 80, 1),
    (2, 384, 1, 8, 128, 200),
    (1, 256, 4, 2, 32, 100),
]


@pytest.mark.parametrize("b,s,kv,g,hd,vl", CASES)
def test_fused_matches_ref(b, s, kv, g, hd, vl):
    q, k, v, kscale, vscale = _case(
        jax.random.PRNGKey(b * s + kv + g + hd), b, s, kv, g, hd)
    got = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(vl),
                               interpret=True)
    want = ref.decode_attention_int8_ref(q, k, v, kscale, vscale,
                                         jnp.int32(vl))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_cpu_fallback_matches_interpret():
    """ops.decode_attention's CPU fallback (oracle) and the interpreted
    kernel body agree — interchangeable implementations."""
    q, k, v, kscale, vscale = _case(jax.random.PRNGKey(7), 2, 128, 2, 4, 64)
    a = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(77),
                             interpret=True)
    b = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(77),
                             interpret=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_empty_cache_returns_zeros():
    q, k, v, kscale, vscale = _case(jax.random.PRNGKey(1), 1, 128, 1, 2, 64)
    out = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(0),
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_masked_slots_do_not_leak():
    """Garbage in slots >= valid_len must not affect the output."""
    key = jax.random.PRNGKey(3)
    q, k, v, kscale, vscale = _case(key, 1, 256, 1, 4, 64)
    vl = 100
    k2 = k.at[:, vl:].set(127)
    v2 = v.at[:, vl:].set(-127)
    ks2 = kscale.at[:, vl:].set(1e3)
    vs2 = vscale.at[:, vl:].set(1e3)
    a = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(vl),
                             interpret=True)
    b = ops.decode_attention(q, k2, v2, ks2, vs2, jnp.int32(vl),
                             interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


@given(st.integers(0, 2**31 - 1), st.sampled_from([17, 128, 200]))
@settings(max_examples=8, deadline=None)
def test_rows_sum_property(seed, vl):
    """With v = unit-dequant ones, every output element must be exactly 1
    (softmax rows sum to 1) regardless of mask — catches denominator and
    v-scale-folding bugs."""
    key = jax.random.PRNGKey(seed)
    b, s, kv, g, hd = 1, 256, 2, 2, 64
    q, k, _, kscale, _ = _case(key, b, s, kv, g, hd)
    v = jnp.ones((b, s, kv, hd), jnp.int8)
    vscale = jnp.ones((b, s, kv, 1), jnp.float32)
    out = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(vl),
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


def _case_new(key, b, kv, hd):
    k1, k2 = jax.random.split(key)
    kn = jax.random.normal(k1, (b, 1, kv, hd), jnp.float32)
    vn = jax.random.normal(k2, (b, 1, kv, hd), jnp.float32)
    return kn, vn


@pytest.mark.parametrize("b,s,kv,g,hd,vl", CASES)
def test_append_path_matches_ref(b, s, kv, g, hd, vl):
    """Append path: the current token's k/v as an extra kernel operand
    folded into the online softmax at the final sweep step."""
    key = jax.random.PRNGKey(b * s + kv + g + hd + 1)
    q, k, v, kscale, vscale = _case(key, b, s, kv, g, hd)
    kn, vn = _case_new(jax.random.fold_in(key, 9), b, kv, hd)
    got = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(vl),
                               k_new=kn, v_new=vn, interpret=True)
    want = ref.decode_attention_int8_ref(q, k, v, kscale, vscale,
                                         jnp.int32(vl), k_new=kn, v_new=vn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_append_path_empty_cache_attends_only_to_self():
    """valid_len=0 + current-token operand: softmax collapses onto the new
    token, so out == v_new exactly (one column, prob 1)."""
    key = jax.random.PRNGKey(5)
    b, s, kv, g, hd = 1, 128, 2, 4, 64
    q, k, v, kscale, vscale = _case(key, b, s, kv, g, hd)
    kn, vn = _case_new(jax.random.fold_in(key, 1), b, kv, hd)
    out = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(0),
                               k_new=kn, v_new=vn, interpret=True)
    want = jnp.broadcast_to(vn[:, 0, :, None, :], (b, kv, g, hd))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_per_row_valid_len_matches_scalar_rows():
    """(B,) valid_len — the slot engine's per-request frontiers — equals
    running each row alone with its scalar valid_len."""
    q, k, v, kscale, vscale = _case(jax.random.PRNGKey(13), 3, 256, 2, 4, 64)
    kn, vn = _case_new(jax.random.PRNGKey(14), 3, 2, 64)
    vls = [0, 100, 256]
    got = ops.decode_attention(q, k, v, kscale, vscale,
                               jnp.array(vls, jnp.int32),
                               k_new=kn, v_new=vn, interpret=True)
    for i, vl in enumerate(vls):
        one = ops.decode_attention(
            q[i:i + 1], k[i:i + 1], v[i:i + 1], kscale[i:i + 1],
            vscale[i:i + 1], jnp.int32(vl), k_new=kn[i:i + 1],
            v_new=vn[i:i + 1], interpret=True)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(one[0]),
                                   rtol=1e-6, atol=1e-6)


def test_append_path_matches_model_einsum():
    """The fused append path agrees with the model's einsum append branch
    (layers.attention append_only=True): cache scores + one self column,
    softmax over the concatenation, v-scale folded on cache probs only."""
    key = jax.random.PRNGKey(21)
    b, s, kv, g, hd = 2, 128, 2, 2, 64
    q, k, v, kscale, vscale = _case(key, b, s, kv, g, hd)
    kn, vn = _case_new(jax.random.fold_in(key, 2), b, kv, hd)
    vl = 90
    got = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(vl),
                               k_new=kn, v_new=vn, interpret=True)
    # the einsum append path as written in layers.attention, f32 contract
    q5 = q[:, None]                                  # (B, 1, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    scores = scores * kscale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    valid = jnp.arange(s)[None, :] < vl
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    s_self = jnp.einsum("bqkgd,btkd->bkgqt", q5.astype(jnp.float32),
                        kn.astype(jnp.float32)) * hd ** -0.5
    scores = jnp.concatenate([scores, s_self], axis=-1)
    probs = jax.nn.softmax(scores, axis=-1)
    pc, pn = probs[..., :s], probs[..., s:]
    pc = pc * vscale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    want = jnp.einsum("bkgqs,bskd->bqkgd", pc, v.astype(jnp.float32)) \
        + jnp.einsum("bkgqt,btkd->bqkgd", pn, vn.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, 0]),
                               rtol=2e-4, atol=2e-4)


def test_matches_model_einsum_decode_path():
    """The fused kernel agrees with the model's XLA einsum decode path
    (layers.attention quantized branch) on a GQA-shaped case: the two are
    interchangeable implementations of the same math."""
    key = jax.random.PRNGKey(11)
    b, s, kv, g, hd = 2, 128, 2, 2, 64
    q, k, v, kscale, vscale = _case(key, b, s, kv, g, hd)
    vl = 90
    got = ops.decode_attention(q, k, v, kscale, vscale, jnp.int32(vl),
                               interpret=True)
    # the einsum path as written in layers.attention (scores/probs scale
    # folding, bf16 contractions) — rebuilt here with f32 contractions
    q5 = q[:, None]                                  # (B, 1, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    scores = scores * kscale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    valid = jnp.arange(s)[None, :] < vl
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs * vscale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    want = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    want = want[:, 0]                                # (B, KV, G, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
