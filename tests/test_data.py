"""Data pipeline: determinism, resumability, host sharding."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.data import SyntheticLMData


def test_deterministic_across_instances():
    a = SyntheticLMData(vocab=512, seq_len=32, global_batch=8, seed=7)
    b = SyntheticLMData(vocab=512, seq_len=32, global_batch=8, seed=7)
    ta, la = a.batch_at(13)
    tb, lb = b.batch_at(13)
    np.testing.assert_array_equal(ta, tb)
    np.testing.assert_array_equal(la, lb)


def test_resume_reproduces_stream():
    """batch_at(t) is a pure function of (seed, t) — restart-safe."""
    d = SyntheticLMData(vocab=512, seq_len=32, global_batch=8, seed=1)
    run1 = [d.batch_at(t)[0] for t in range(6)]
    run2 = [d.batch_at(t)[0] for t in range(3, 6)]   # "resume at step 3"
    for a, b in zip(run1[3:], run2):
        np.testing.assert_array_equal(a, b)


def test_different_steps_differ():
    d = SyntheticLMData(vocab=512, seq_len=32, global_batch=8)
    assert not np.array_equal(d.batch_at(0)[0], d.batch_at(1)[0])


def test_labels_are_shifted_tokens():
    d = SyntheticLMData(vocab=512, seq_len=32, global_batch=4)
    tokens, labels = d.batch_at(0)
    # same underlying sequence: tokens[1:] == labels[:-1]
    np.testing.assert_array_equal(tokens[:, 1:], labels[:, :-1])


@given(st.integers(1, 8).filter(lambda n: 16 % n == 0))
@settings(max_examples=8, deadline=None)
def test_host_slices_partition_global_batch(host_count):
    d = SyntheticLMData(vocab=512, seq_len=16, global_batch=16)
    full, _ = d.batch_at(5)
    parts = [d.batch_at(5, host_index=i, host_count=host_count)[0]
             for i in range(host_count)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_tokens_within_vocab():
    d = SyntheticLMData(vocab=100, seq_len=64, global_batch=4)
    tokens, labels = d.batch_at(2)
    assert tokens.min() >= 0 and tokens.max() < 100
    assert labels.min() >= 0 and labels.max() < 100


def test_motifs_give_learnable_structure():
    """Repeated motifs: bigram entropy must be well below iid-uniform."""
    d = SyntheticLMData(vocab=512, seq_len=256, global_batch=8, seed=0)
    tokens, _ = d.batch_at(0)
    # count repeated 8-grams across batch: motifs recur, iid tokens don't
    from collections import Counter
    grams = Counter()
    for row in tokens:
        for i in range(0, len(row) - 8, 4):
            grams[tuple(row[i:i + 8])] += 1
    assert max(grams.values()) >= 2
