import os
import sys
import tempfile

import pytest

# tests run against src/ without installation; tests/ itself must also be
# importable for the _hypothesis_compat fallback shim
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Smoke tests and benches must see the real single-CPU device topology.
# (Only launch/dryrun.py forces 512 host devices, in its own process.)
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not inherit the dry-run's 512-device override"

# Hermetic autotune cache: a fresh per-session file so kernel-dispatch tests
# never read (or pollute) the user's tile winners — a forced override, since
# a developer's exported REPRO_AUTOTUNE_CACHE must not leak into the suite.
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro_autotune_"), "autotune.json")


def pytest_collection_modifyitems(config, items):
    """The suite must stay green offline: anything marked `network` is
    skipped unless the caller explicitly opts in."""
    if os.environ.get("REPRO_ALLOW_NETWORK") == "1":
        return
    skip = pytest.mark.skip(
        reason="needs network (set REPRO_ALLOW_NETWORK=1 to enable)")
    for item in items:
        if "network" in item.keywords:
            item.add_marker(skip)
