import os
import sys

# tests run against src/ without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Smoke tests and benches must see the real single-CPU device topology.
# (Only launch/dryrun.py forces 512 host devices, in its own process.)
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", ""), \
    "tests must not inherit the dry-run's 512-device override"
