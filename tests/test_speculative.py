"""Speculative decoding through the slot engine: bit-for-bit acceptance.

The whole contract is one sentence — with ``spec_k > 0`` the engine's
committed output stream is byte-identical to the non-speculative
engine's, whatever the draft proposes — so every test here is some form
of equality against the plain engine or the sequential reference:

- per opted-in family (dense, windowless moe), greedy AND sampled, on
  200-request continuous-batching traces;
- a full-depth self-draft (``draft_layers = n_layers``) IS the target,
  so every proposal must be accepted (the acceptance upper bound);
- a garbage draft (same arch, different init) whose proposals are
  teacher-forced into the target cache and then rejected proves the
  rejected tail's KV writes are dead (decode-contract rule 7), paged
  and contiguous;
- preemption mid-speculation and a seeded FaultPlan (hypothesis-driven)
  compose with exact resume: in-flight proposals are uncommitted state;
- families whose decode state cannot rewind (recurrent, windowed,
  primed) are refused at construction, and the new accounting columns
  (``accepted_per_dispatch``, ``latency_per_token_s``) are exact.
"""
import dataclasses

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine as E
from repro.configs import get_config
from repro.core import batching as bt
from repro.models import registry as R

KEY = jax.random.PRNGKey(0)
SPEC_FAMILIES = ["starcoder2-3b", "qwen2-moe-a2.7b"]


def _trace(cfg, n=200, rate=3000.0, prompt_len=4, max_new=6, seed=0,
           **kw):
    return E.synthetic_requests(n, rate_per_s=rate, vocab=cfg.vocab,
                                prompt_len=prompt_len,
                                max_new_tokens=max_new, seed=seed, **kw)


@pytest.fixture(scope="module", params=SPEC_FAMILIES)
def family_setup(request):
    cfg = get_config(request.param).reduced()
    return cfg, R.init(KEY, cfg)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("starcoder2-3b").reduced()
    return cfg, R.init(KEY, cfg)


# ---------------------------------------------------------------------------
# registry hooks
# ---------------------------------------------------------------------------

class TestRegistryHooks:
    def test_speculation_support_is_positional_kv_only(self):
        """Exactly the families whose decode state is rewindable
        positional KV opt in; recurrent state, sliding windows, and
        primed cross-attention are out."""
        want = {"starcoder2-3b": True, "qwen2-moe-a2.7b": True,
                "mixtral-8x22b": False,        # sliding window
                "mamba2-1.3b": False,          # recurrent ssm state
                "recurrentgemma-9b": False,    # recurrent + windowed
                "whisper-medium": False,       # primed cross-attention
                "llama-3.2-vision-90b": False}
        for name, ok in want.items():
            cfg = get_config(name).reduced()
            assert R.supports_speculation(cfg) == ok, name
            assert R.supports_self_draft(cfg) == ok, name

    def test_draft_config_truncates_and_renames(self):
        cfg = get_config("starcoder2-3b").reduced()
        d = R.draft_config(cfg, 1)
        assert d.n_layers == 1 and d.vocab == cfg.vocab
        assert d.name == cfg.name + "-draft1"
        with pytest.raises(ValueError):
            R.draft_config(cfg, 0)
        with pytest.raises(ValueError):
            R.draft_config(cfg, cfg.n_layers + 1)

    def test_draft_params_is_a_shared_view(self, dense_setup):
        """The self-draft tree slices the stacked layers and shares the
        embed/norm/unembed leaves by reference — no second checkpoint,
        no copy of the kept weights."""
        cfg, params = dense_setup
        dp = R.draft_params(cfg, params, 1)
        assert dp["embed"] is params["embed"]
        assert dp["ln_f"] is params["ln_f"]
        for a, b in zip(jax.tree_util.tree_leaves(dp["layers"]),
                        jax.tree_util.tree_leaves(params["layers"])):
            assert a.shape[0] == 1 and b.shape[0] == cfg.n_layers

    def test_draft_params_refuses_non_speculative_families(self):
        cfg = get_config("mamba2-1.3b").reduced()
        with pytest.raises(ValueError, match="self-draft"):
            R.draft_params(cfg, {}, 1)


# ---------------------------------------------------------------------------
# construction contract
# ---------------------------------------------------------------------------

class TestConstruction:
    def test_spec_needs_exactly_one_draft_source(self, dense_setup):
        cfg, params = dense_setup
        with pytest.raises(ValueError, match="exactly one"):
            E.Engine(cfg, params, spec_k=2)
        with pytest.raises(ValueError, match="exactly one"):
            E.Engine(cfg, params, spec_k=2, draft_layers=1,
                     draft=(cfg, params))
        with pytest.raises(ValueError, match="spec_k"):
            E.Engine(cfg, params, spec_k=-1)
        with pytest.raises(ValueError, match="spec_k >= 1"):
            E.Engine(cfg, params, draft_layers=1)

    def test_rejects_unrewindable_targets_and_drafts(self, dense_setup):
        cfg, params = dense_setup
        for name in ("mixtral-8x22b", "mamba2-1.3b"):
            bad = get_config(name).reduced()
            bad_params = R.init(KEY, bad)
            with pytest.raises(ValueError, match="rewindable"):
                E.Engine(bad, bad_params, spec_k=2, draft_layers=1)
            with pytest.raises(ValueError, match="rewindable"):
                E.Engine(cfg, params, spec_k=2, draft=(bad, bad_params))

    def test_rejects_vocab_mismatch(self, dense_setup):
        cfg, params = dense_setup
        dcfg = dataclasses.replace(cfg, name="wrong-vocab",
                                   vocab=cfg.vocab * 2)
        with pytest.raises(ValueError, match="vocab"):
            E.Engine(cfg, params, spec_k=2,
                     draft=(dcfg, R.init(KEY, dcfg)))


# ---------------------------------------------------------------------------
# bit-for-bit acceptance, per opted-in family
# ---------------------------------------------------------------------------

class TestBitForBit:
    def test_greedy_200_requests(self, family_setup):
        """Acceptance: the speculative engine's outputs on a 200-request
        continuous-batching trace equal the plain engine's byte for
        byte, and speculation actually pays (fewer ticks, > 1 token per
        emitting dispatch)."""
        cfg, params = family_setup
        reqs = _trace(cfg)
        plain = E.Engine(cfg, params, num_slots=4, max_seq=16).serve(reqs)
        spec = E.Engine(cfg, params, num_slots=4, max_seq=16,
                        spec_k=3, draft_layers=1).serve(reqs)
        assert spec.outputs() == plain.outputs()
        assert len(spec.results) == 200
        assert all(r.status == "ok" for r in spec.results)
        assert spec.generated_tokens == plain.generated_tokens
        assert spec.accepted_per_dispatch > 1.0
        assert spec.ticks < plain.ticks

    def test_sampled_200_requests(self, family_setup):
        """The same equality under temperature sampling: the verify
        scan's per-position fold_in(rng, position) keys reproduce the
        slot step's draws exactly, so acceptance stays bitwise beyond
        greedy."""
        cfg, params = family_setup
        rng = jax.random.PRNGKey(11)
        reqs = _trace(cfg, seed=1)
        plain = E.Engine(cfg, params, num_slots=4, max_seq=16,
                         temperature=0.7, rng=rng).serve(reqs)
        spec = E.Engine(cfg, params, num_slots=4, max_seq=16,
                        temperature=0.7, rng=rng,
                        spec_k=2, draft_layers=1).serve(reqs)
        assert spec.outputs() == plain.outputs()
        assert all(r.status == "ok" for r in spec.results)

    def test_full_depth_self_draft_accepts_everything(self, dense_setup):
        """draft_layers = n_layers makes the draft the target: every
        proposal must be accepted, so with max_new divisible by k+1
        every emitting dispatch commits exactly k+1 tokens."""
        cfg, params = dense_setup
        k = 3
        reqs = _trace(cfg, n=24, max_new=8, seed=2)
        plain = E.Engine(cfg, params, num_slots=4, max_seq=16).serve(reqs)
        spec = E.Engine(cfg, params, num_slots=4, max_seq=16,
                        spec_k=k, draft_layers=cfg.n_layers).serve(reqs)
        assert spec.outputs() == plain.outputs()
        assert spec.accepted_per_dispatch == pytest.approx(k + 1)
        assert spec.ticks < plain.ticks

    def test_cross_model_draft(self, dense_setup):
        """A separate draft checkpoint (different arch dims, same vocab)
        — the starcoder2-3b-drafts-for-qwen2-moe configuration."""
        cfg = get_config("qwen2-moe-a2.7b").reduced()
        params = R.init(KEY, cfg)
        dcfg, dparams = dense_setup
        assert dcfg.vocab == cfg.vocab
        reqs = _trace(cfg, n=40, seed=3)
        plain = E.Engine(cfg, params, num_slots=4, max_seq=16).serve(reqs)
        spec = E.Engine(cfg, params, num_slots=4, max_seq=16,
                        spec_k=2, draft=(dcfg, dparams)).serve(reqs)
        assert spec.outputs() == plain.outputs()


# ---------------------------------------------------------------------------
# rejected speculative KV writes are dead (decode-contract rule 7)
# ---------------------------------------------------------------------------

class TestSpeculativePoison:
    @pytest.mark.parametrize("paged", [False, True])
    def test_garbage_draft_cannot_corrupt_the_target(self, dense_setup,
                                                     paged):
        """A draft initialized from a different seed proposes tokens the
        target mostly rejects — yet every proposal WAS teacher-forced
        into the target cache at positions past the committed frontier
        before being rewound.  Byte-equality of the committed stream is
        the proof those speculative writes are dead: overwritten before
        any read can see them, in private blocks only (never shared or
        registered ones)."""
        cfg, params = dense_setup
        garbage = R.init(jax.random.PRNGKey(666), cfg)
        kw = dict(block_size=4, prefill_chunk=4) if paged else {}
        reqs = _trace(cfg, n=60, seed=4, prompt_len=6 if paged else 4,
                      shared_prefix_len=4 if paged else 0)
        plain = E.Engine(cfg, params, num_slots=4, max_seq=16,
                         **kw).serve(reqs)
        spec = E.Engine(cfg, params, num_slots=4, max_seq=16, spec_k=3,
                        draft=(cfg, garbage), **kw).serve(reqs)
        assert spec.outputs() == plain.outputs()
        # every dispatch still commits its bonus token even when every
        # proposal is rejected — the floor of the accounting identity
        assert spec.accepted_per_dispatch >= 1.0
        if paged:
            assert spec.leaked_blocks == 0
            assert spec.shared_block_hits > 0


# ---------------------------------------------------------------------------
# composition: preemption mid-speculation, faults, exact resume
# ---------------------------------------------------------------------------

class TestChaosComposition:
    def test_preemption_mid_speculation_resumes_exactly(self, dense_setup):
        """Slot preemption lands between speculative rounds with the
        draft cache mid-stream; on resume the draft frontier is rebuilt
        from zero (alloc resets it) and the committed output is still
        the never-preempted output."""
        cfg, params = dense_setup
        reqs = _trace(cfg, n=30, rate=2000.0, prompt_len=3, max_new=5,
                      seed=5,
                      priority=lambda rid: ("batch" if rid % 3 == 0
                                            else "interactive"))
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       block_size=4, num_blocks=9, prefill_chunk=2,
                       spec_k=3, draft_layers=1)
        rep = eng.serve(reqs, preemption=True)
        assert rep.preempted > 0
        assert any(r.preemptions > 0 for r in rep.results)
        assert rep.outputs() == want
        assert all(r.status == "ok" for r in rep.results)
        assert rep.leaked_blocks == 0

    @given(st.integers(0, 1000))
    @settings(max_examples=5, deadline=None)
    def test_fault_plan_chaos_stays_bit_for_bit(self, seed):
        """Seeded dispatch faults, non-finite logits, and torn block-
        table rows against the speculating engine: any fault inside a
        speculative round discards the WHOLE round (in-flight proposals
        are uncommitted state), recovery rebuilds from the last
        committed token, and every ok request still matches the
        sequential reference."""
        cfg = get_config("starcoder2-3b").reduced()
        params = R.init(KEY, cfg)
        reqs = _trace(cfg, n=30, rate=8000.0, seed=6,
                      priority=lambda rid: bt.PRIORITY_CLASSES[rid % 2])
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        plan = E.FaultPlan.random(seed=seed, n_faults=10, max_tick=200,
                                  num_slots=4)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       block_size=4, num_blocks=13, prefill_chunk=4,
                       spec_k=2, draft_layers=1)
        rep = eng.serve(reqs, preemption=True, fault_plan=plan)
        assert len(rep.results) == 30
        for r in rep.results:
            if r.status == "ok":
                assert r.tokens == want[r.rid], r.rid
        assert rep.leaked_blocks == 0


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

class TestAccounting:
    def test_non_speculative_identity(self, dense_setup):
        """Without speculation every emitting dispatch commits exactly
        one token: accepted_per_dispatch is 1.0 EXACTLY, and the
        per-token latency mean is positive and finite."""
        cfg, params = dense_setup
        rep = E.Engine(cfg, params, num_slots=4, max_seq=16).serve(
            _trace(cfg, n=20, seed=7))
        assert rep.spec_k == 0
        assert rep.accepted_per_dispatch == 1.0
        assert 0.0 < rep.latency_per_token_s < float("inf")
        ok = [r for r in rep.results if r.status == "ok" and r.tokens]
        want = float(np.mean([r.latency_s / len(r.tokens) for r in ok]))
        assert rep.latency_per_token_s == pytest.approx(want)

    def test_speculative_tokens_counted_once(self, dense_setup):
        """Throughput counts committed tokens only — a rejected proposal
        never inflates generated_tokens or tokens_per_s."""
        cfg, params = dense_setup
        reqs = _trace(cfg, n=20, seed=8)
        plain = E.Engine(cfg, params, num_slots=4, max_seq=16).serve(reqs)
        spec = E.Engine(cfg, params, num_slots=4, max_seq=16, spec_k=3,
                        draft_layers=1).serve(reqs)
        assert spec.generated_tokens == plain.generated_tokens
        assert spec.generated_tokens == \
            sum(len(r.tokens) for r in spec.results)
        assert spec.spec_k == 3
