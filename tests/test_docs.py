"""Docs cannot silently rot: fenced ``python`` blocks in README.md and
docs/*.md must compile, and every repo path the docs mention must exist.

This is deliberately syntactic (no execution): the point is catching
renamed files, deleted flags and typo'd snippets at test time, not
turning prose into a second test suite."""
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md"))

FENCE = re.compile(r"```(\w+)\n(.*?)```", re.S)
# path-like tokens anywhere in the doc (prose, inline code, bash blocks):
# a known top-level directory followed by a /-path
PATH = re.compile(r"\b(?:src|docs|benchmarks|tests|examples)/[\w./\-]+")


def _read(rel):
    with open(os.path.join(REPO, rel)) as f:
        return f.read()


def _fenced_blocks(lang):
    out = []
    for rel in DOC_FILES:
        for m in FENCE.finditer(_read(rel)):
            if m.group(1) == lang:
                out.append((rel, m.group(2)))
    return out


def test_docs_exist():
    assert "README.md" in DOC_FILES
    names = {os.path.basename(p) for p in DOC_FILES}
    assert {"architecture.md", "serving.md", "autotune.md"} <= names


def test_python_blocks_compile():
    blocks = _fenced_blocks("python")
    assert blocks, "docs should contain at least one python block"
    for rel, src in blocks:
        try:
            compile(src, f"<{rel}>", "exec")
        except SyntaxError as e:  # pragma: no cover - failure reporting
            pytest.fail(f"python block in {rel} does not compile: {e}")


def test_bash_blocks_reference_real_entrypoints():
    blocks = _fenced_blocks("bash")
    assert blocks, "docs should contain at least one bash block"
    for rel, src in blocks:
        for script in re.findall(r"python\s+(?:-m\s+)?(\S+)", src):
            if script.endswith(".py"):           # script form
                path = os.path.join(REPO, script)
            elif script.startswith("repro."):    # module form -> src/
                path = os.path.join(REPO, "src",
                                    script.replace(".", os.sep) + ".py")
            else:                                # stdlib/third-party module
                continue
            assert os.path.exists(path), \
                f"{rel}: bash block runs {script!r} but {path} is missing"


def test_referenced_repo_paths_exist():
    checked = 0
    for rel in DOC_FILES:
        for tok in PATH.findall(_read(rel)):
            tok = tok.rstrip(".").split(":")[0]   # strip sentence period,
            if "*" in tok:                        # line refs, glob patterns
                continue
            assert os.path.exists(os.path.join(REPO, tok)), \
                f"{rel} references {tok!r}, which does not exist"
            checked += 1
    assert checked > 20, "path check should cover the docs' references"
