"""HLO cost model: trip counts, slice-aware bytes, collective accounting.

Two tiers: golden-fixture tests parse checked-in HLO text (milliseconds, no
JAX compilation — see tests/fixtures/hlo/regen.py), while the compiled-module
tests lower real programs through the installed XLA as integration checks.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost as HC
from repro.core import roofline as RL

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "hlo")


def _fixture(name):
    with open(os.path.join(FIXTURES, name)) as f:
        return f.read()


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestGoldenFixtures:
    """Core parsing cases against checked-in post-SPMD HLO text."""

    def test_scan_flops_exact(self):
        t = HC.analyze(_fixture("scan_matmul.hlo"))
        assert t.flops == 2 * 64 * 128 * 128 * 8
        assert t.unparsed_whiles == 0

    def test_scan_weight_slices_not_full_stack(self):
        t = HC.analyze(_fixture("scan_matmul.hlo"))
        stack_bytes = 8 * 128 * 128 * 4
        assert t.bytes < 6 * stack_bytes

    def test_nested_scan_flops(self):
        assert HC.analyze(_fixture("nested_scan.hlo")).flops == \
            2 * 64 * 128 * 128 * 24

    def test_fusion_with_dot(self):
        t = HC.analyze(_fixture("fusion_dot.hlo"))
        assert t.flops == 2 * 32 * 64 * 16
        # all flops attributed to the dot in the per-op breakdown
        assert t.by_op["dot"].flops == t.flops
        # the gelu+bias tail is an elementwise-only fusion: free bytes
        assert "fusion" not in t.by_op

    def test_dus_charged_at_update_region(self):
        t = HC.analyze(_fixture("dus_donated.hlo"))
        update_bytes = 4 * 1 * 64 * 4
        full_cache = 4 * 1024 * 64 * 4
        assert t.bytes == 2 * update_bytes
        assert t.bytes < full_cache

    def test_psum_bytes_and_count(self):
        t = HC.analyze(_fixture("psum.hlo"))
        # all-reduce over the f32[128,128] partial product
        assert t.collective_bytes == 128 * 128 * 4
        assert t.collective_counts["all-reduce"] == 1
        assert t.collective_bytes_by_op["all-reduce"] == t.collective_bytes

    def test_collective_inside_scan_trip_multiplied(self):
        t = HC.analyze(_fixture("scan_psum.hlo"))
        # one f32[16,64] all-reduce per iteration, 8 iterations
        assert t.collective_counts["all-reduce"] == 8
        assert t.collective_bytes == 8 * 16 * 64 * 4

    def test_by_op_totals_are_consistent(self):
        for name in ("scan_matmul.hlo", "fusion_dot.hlo", "psum.hlo",
                     "scan_psum.hlo", "dus_donated.hlo",
                     "nested_scan.hlo"):
            t = HC.analyze(_fixture(name))
            assert sum(oc.flops for oc in t.by_op.values()) == \
                pytest.approx(t.flops)
            assert sum(oc.bytes for oc in t.by_op.values()) == \
                pytest.approx(t.bytes)

    def test_structural_parse_resolves_operand_shapes(self):
        """The regex line-walker split `f32[64,128]` at the inner comma and
        lost the dot contraction; the structural parser must not."""
        module = HC.parse_hlo(_fixture("scan_matmul.hlo"))
        dots = [(comp, ins) for comp in module.computations.values()
                for ins in comp.instrs.values() if ins.opcode == "dot"]
        assert dots
        comp, dot = dots[0]
        lhs = comp.shapes_of(dot.operands[0])
        assert lhs and lhs[0].dims == (64, 128)


class TestTripCounts:
    def test_scan_flops_exact(self):
        def f(x, w):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(body, x, w)[0]
        c = _compiled(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                      jax.ShapeDtypeStruct((8, 128, 128), jnp.float32))
        t = HC.analyze(c.as_text())
        assert t.flops == 2 * 64 * 128 * 128 * 8
        assert t.unparsed_whiles == 0

    def test_nested_scan(self):
        def g(x, w):
            def outer(x, wi):
                def inner(x, _):
                    return jnp.tanh(x @ wi), None
                return jax.lax.scan(inner, x, None, length=3)[0], None
            return jax.lax.scan(outer, x, w)[0]
        c = _compiled(g, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                      jax.ShapeDtypeStruct((8, 128, 128), jnp.float32))
        assert HC.analyze(c.as_text()).flops == 2 * 64 * 128 * 128 * 24

    def test_unrolled_matches_scan(self):
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)

        def scan_f(x, w):
            return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

        def unroll_f(x, w):
            for i in range(4):
                x = x @ w[i]
            return x
        fs = HC.analyze(_compiled(scan_f, x, w).as_text()).flops
        fu = HC.analyze(_compiled(unroll_f, x, w).as_text()).flops
        assert fs == fu == 2 * 32 * 64 * 64 * 4


class TestSliceAwareBytes:
    def test_scan_weight_slices_not_full_stack(self):
        """Each iteration reads ONE (128,128) weight slice, not the whole
        (64,128,128) stack; total weight bytes ~ stack size, not 64x it."""
        def f(x, w):
            return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
        c = _compiled(f, jax.ShapeDtypeStruct((8, 128), jnp.float32),
                      jax.ShapeDtypeStruct((64, 128, 128), jnp.float32))
        t = HC.analyze(c.as_text())
        stack_bytes = 64 * 128 * 128 * 4
        # bound: weights once + activations; far below 64x the stack
        assert t.bytes < 6 * stack_bytes

    def test_dynamic_update_slice_charged_at_update(self):
        def f(cache, new):
            return jax.lax.dynamic_update_slice(cache, new, (0, 5, 0))
        # donated buffer -> in-place update, no defensive copy (this is how
        # the decode path runs; without donation XLA inserts a full copy,
        # which IS real traffic and is charged)
        c = jax.jit(f, donate_argnums=(0,)).lower(
            jax.ShapeDtypeStruct((4, 1024, 64), jnp.float32),
            jax.ShapeDtypeStruct((4, 1, 64), jnp.float32)).compile()
        t = HC.analyze(c.as_text())
        full = 4 * 1024 * 64 * 4
        assert t.bytes < full  # must NOT charge the full cache


class TestCollectives:
    def test_psum_counted(self):
        import subprocess, sys, os, textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import hlo_cost as HC
            mesh = jax.make_mesh((4,), ("x",))
            def f(a, b):
                return (a @ b)
            a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
            b = jax.ShapeDtypeStruct((256, 128), jnp.float32)
            sa = NamedSharding(mesh, P(None, "x"))
            sb = NamedSharding(mesh, P("x", None))
            with mesh:
                c = jax.jit(f, in_shardings=(sa, sb),
                            out_shardings=NamedSharding(mesh, P())) \
                    .lower(a, b).compile()
            t = HC.analyze(c.as_text())
            assert t.collective_bytes > 0, "contraction over sharded dim \
needs an all-reduce"
            print("OK")
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))), timeout=120)
        assert r.returncode == 0, r.stderr[-1500:]

    def test_collective_inside_scan_multiplied(self):
        """The loop multiplies collective bytes AND counts: a single
        all-reduce instruction in an 8-trip while body counts 8 times."""
        t = HC.analyze(_fixture("scan_psum.hlo"))
        single = HC.analyze(_fixture("psum.hlo"))
        assert t.collective_counts["all-reduce"] == 8
        assert single.collective_counts["all-reduce"] == 1
        assert t.collective_bytes == 8 * (16 * 64 * 4)


class TestRooflineTerms:
    def test_terms_and_bound(self):
        def f(a, b):
            return (a @ b).sum()
        c = _compiled(f, jax.ShapeDtypeStruct((256, 512), jnp.float32),
                      jax.ShapeDtypeStruct((512, 128), jnp.float32))
        t = RL.from_compiled("tiny", c, chips=1,
                             model_flops=2 * 256 * 512 * 128)
        assert t.compute_s > 0 and t.memory_s > 0
        assert t.bound in ("compute", "memory", "collective")
        assert 0.9 < t.useful_flops_frac <= 1.05
        d = t.to_dict()
        assert d["cell"] == "tiny"

    def test_flops_match_model_flops_exactly_for_pure_matmul(self):
        def f(a, b):
            return a @ b
        c = _compiled(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                      jax.ShapeDtypeStruct((128, 32), jnp.float32))
        t = RL.from_compiled("mm", c, chips=1, model_flops=2 * 64 * 128 * 32)
        assert t.hlo_flops == t.model_flops


def test_watchdog_detects_stragglers():
    from repro.runtime.watchdog import StepWatchdog
    w = StepWatchdog(warmup_steps=0, threshold=2.0)
    for _ in range(10):
        assert w.record(0.1) is None
    msg = w.record(0.5)
    assert msg is not None and "straggler" in msg
    assert w.slow_steps == 1
    # normal step after the spike: no warning
    assert w.record(0.11) is None
