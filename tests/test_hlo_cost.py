"""HLO cost model: trip counts, slice-aware bytes, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost as HC
from repro.core import roofline as RL


def _compiled(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestTripCounts:
    def test_scan_flops_exact(self):
        def f(x, w):
            def body(x, wi):
                return jnp.tanh(x @ wi), None
            return jax.lax.scan(body, x, w)[0]
        c = _compiled(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                      jax.ShapeDtypeStruct((8, 128, 128), jnp.float32))
        t = HC.analyze(c.as_text())
        assert t.flops == 2 * 64 * 128 * 128 * 8
        assert t.unparsed_whiles == 0

    def test_nested_scan(self):
        def g(x, w):
            def outer(x, wi):
                def inner(x, _):
                    return jnp.tanh(x @ wi), None
                return jax.lax.scan(inner, x, None, length=3)[0], None
            return jax.lax.scan(outer, x, w)[0]
        c = _compiled(g, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                      jax.ShapeDtypeStruct((8, 128, 128), jnp.float32))
        assert HC.analyze(c.as_text()).flops == 2 * 64 * 128 * 128 * 24

    def test_unrolled_matches_scan(self):
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)

        def scan_f(x, w):
            return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

        def unroll_f(x, w):
            for i in range(4):
                x = x @ w[i]
            return x
        fs = HC.analyze(_compiled(scan_f, x, w).as_text()).flops
        fu = HC.analyze(_compiled(unroll_f, x, w).as_text()).flops
        assert fs == fu == 2 * 32 * 64 * 64 * 4


class TestSliceAwareBytes:
    def test_scan_weight_slices_not_full_stack(self):
        """Each iteration reads ONE (128,128) weight slice, not the whole
        (64,128,128) stack; total weight bytes ~ stack size, not 64x it."""
        def f(x, w):
            return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
        c = _compiled(f, jax.ShapeDtypeStruct((8, 128), jnp.float32),
                      jax.ShapeDtypeStruct((64, 128, 128), jnp.float32))
        t = HC.analyze(c.as_text())
        stack_bytes = 64 * 128 * 128 * 4
        # bound: weights once + activations; far below 64x the stack
        assert t.bytes < 6 * stack_bytes

    def test_dynamic_update_slice_charged_at_update(self):
        def f(cache, new):
            return jax.lax.dynamic_update_slice(cache, new, (0, 5, 0))
        # donated buffer -> in-place update, no defensive copy (this is how
        # the decode path runs; without donation XLA inserts a full copy,
        # which IS real traffic and is charged)
        c = jax.jit(f, donate_argnums=(0,)).lower(
            jax.ShapeDtypeStruct((4, 1024, 64), jnp.float32),
            jax.ShapeDtypeStruct((4, 1, 64), jnp.float32)).compile()
        t = HC.analyze(c.as_text())
        full = 4 * 1024 * 64 * 4
        assert t.bytes < full  # must NOT charge the full cache


class TestCollectives:
    def test_psum_counted(self):
        import subprocess, sys, os, textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
            from repro.core import hlo_cost as HC
            mesh = jax.make_mesh((4,), ("x",), axis_types=(AxisType.Auto,))
            def f(a, b):
                return (a @ b)
            a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
            b = jax.ShapeDtypeStruct((256, 128), jnp.float32)
            sa = NamedSharding(mesh, P(None, "x"))
            sb = NamedSharding(mesh, P("x", None))
            with mesh:
                c = jax.jit(f, in_shardings=(sa, sb),
                            out_shardings=NamedSharding(mesh, P())) \
                    .lower(a, b).compile()
            t = HC.analyze(c.as_text())
            assert t.collective_bytes > 0, "contraction over sharded dim \
needs an all-reduce"
            print("OK")
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))), timeout=120)
        assert r.returncode == 0, r.stderr[-1500:]

    def test_collective_inside_scan_multiplied(self):
        """parse_collectives (flat) vs hlo_cost (trip-aware): the loop
        multiplies collective bytes."""
        pass  # covered by the dry-run integration below


class TestRooflineTerms:
    def test_terms_and_bound(self):
        def f(a, b):
            return (a @ b).sum()
        c = _compiled(f, jax.ShapeDtypeStruct((256, 512), jnp.float32),
                      jax.ShapeDtypeStruct((512, 128), jnp.float32))
        t = RL.from_compiled("tiny", c, chips=1,
                             model_flops=2 * 256 * 512 * 128)
        assert t.compute_s > 0 and t.memory_s > 0
        assert t.bound in ("compute", "memory", "collective")
        assert 0.9 < t.useful_flops_frac <= 1.05
        d = t.to_dict()
        assert d["cell"] == "tiny"

    def test_flops_match_model_flops_exactly_for_pure_matmul(self):
        def f(a, b):
            return a @ b
        c = _compiled(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                      jax.ShapeDtypeStruct((128, 32), jnp.float32))
        t = RL.from_compiled("mm", c, chips=1, model_flops=2 * 64 * 128 * 32)
        assert t.hlo_flops == t.model_flops


def test_watchdog_detects_stragglers():
    from repro.runtime.watchdog import StepWatchdog
    w = StepWatchdog(warmup_steps=0, threshold=2.0)
    for _ in range(10):
        assert w.record(0.1) is None
    msg = w.record(0.5)
    assert msg is not None and "straggler" in msg
    assert w.slow_steps == 1
    # normal step after the spike: no warning
    assert w.record(0.11) is None
