"""Fused multi-token decode loop: parity with the per-token Python loop,
cache donation safety, and the batch-bucketing ladder."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import registry as R
from repro.runtime import steps as ST

KEY = jax.random.PRNGKey(0)


def test_bucket_batch_ladder():
    assert ST.bucket_batch(1) == 1
    assert ST.bucket_batch(3) == 4
    assert ST.bucket_batch(16) == 16
    assert ST.bucket_batch(17) == 32
    assert ST.bucket_batch(300) == 512      # powers of two past the ladder
    with pytest.raises(ValueError):
        ST.bucket_batch(0)


def test_bucket_batch_capped_at_max_bucket():
    """The power-of-two extension stops at MAX_BUCKET: the compiled-shape
    set is bounded, and oversized batches raise instead of silently
    minting a new compilation."""
    assert ST.bucket_batch(ST.MAX_BUCKET) == ST.MAX_BUCKET
    assert ST.bucket_batch(ST.MAX_BUCKET - 1) == ST.MAX_BUCKET
    with pytest.raises(ValueError, match="MAX_BUCKET"):
        ST.bucket_batch(ST.MAX_BUCKET + 1)
    # explicit override: the cap is a deliberate knob, not a constant
    assert ST.bucket_batch(ST.MAX_BUCKET + 1,
                           max_bucket=4 * ST.MAX_BUCKET) == 2 * ST.MAX_BUCKET


def test_decode_loop_temperature_matches_python_loop():
    """Fused loop with temperature sampling == per-token Python loop with
    the same fold_in(rng, position) key schedule."""
    cfg = get_config("starcoder2-3b").reduced()
    params = R.init(KEY, cfg)
    n_tok, temp = 5, 0.8
    rng = jax.random.PRNGKey(123)
    tok0 = jnp.array([[1], [2]], jnp.int32)

    decode = jax.jit(ST.make_decode_step(cfg))
    cache = R.init_cache(cfg, 2, 32)
    tok, toks = tok0, []
    for i in range(n_tok):
        logits, cache = decode(params,
                               {"tokens": tok,
                                "cache_index": jnp.asarray(i, jnp.int32)},
                               cache)
        nxt = ST.temperature_sample(
            logits, jax.random.fold_in(rng, jnp.asarray(i, jnp.int32)),
            temp)
        tok = nxt[:, None]
        toks.append(nxt)
    want = jnp.stack(toks, axis=1)

    loop = ST.jit_decode_loop(
        ST.make_decode_loop(cfg, num_tokens=n_tok, temperature=temp))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # CPU: donation not usable
        got, _ = loop(params, tok0, R.init_cache(cfg, 2, 32),
                      jnp.zeros((), jnp.int32), rng)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # same key -> same draw; different key -> (almost surely) different
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        again, _ = loop(params, tok0, R.init_cache(cfg, 2, 32),
                        jnp.zeros((), jnp.int32), rng)
        other, _ = loop(params, tok0, R.init_cache(cfg, 2, 32),
                        jnp.zeros((), jnp.int32), jax.random.PRNGKey(7))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(again))
    assert not np.array_equal(np.asarray(got), np.asarray(other))


def test_decode_loop_temperature_requires_rng():
    cfg = get_config("starcoder2-3b").reduced()
    params = R.init(KEY, cfg)
    loop = ST.make_decode_loop(cfg, num_tokens=2, temperature=1.0)
    with pytest.raises(ValueError, match="rng"):
        loop(params, jnp.ones((1, 1), jnp.int32), R.init_cache(cfg, 1, 16),
             jnp.zeros((), jnp.int32))


@pytest.mark.parametrize("arch,kv_quant", [
    ("starcoder2-3b", False),
    ("mistral-nemo-12b", True),     # int8 KV cache through the fused loop
])
def test_decode_loop_matches_python_loop(arch, kv_quant):
    """One jit'd lax.scan over steps == the per-token Python loop."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = R.init(KEY, cfg)
    n_tok = 6
    tok0 = jnp.array([[1], [2]], jnp.int32)

    decode = jax.jit(ST.make_decode_step(cfg))
    cache = R.init_cache(cfg, 2, 32)
    tok, toks = tok0, []
    for i in range(n_tok):
        logits, cache = decode(params,
                               {"tokens": tok,
                                "cache_index": jnp.asarray(i, jnp.int32)},
                               cache)
        tok = ST.greedy_sample(logits)[:, None]
        toks.append(tok[:, 0])
    want = jnp.stack(toks, axis=1)                      # (B, n_tok)

    loop = ST.jit_decode_loop(ST.make_decode_loop(cfg, num_tokens=n_tok))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # CPU: donation not usable
        got, final_cache = loop(params, tok0, R.init_cache(cfg, 2, 32),
                                jnp.zeros((), jnp.int32))
    assert got.shape == (2, n_tok)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # final cache advanced by n_tok steps: same treedef, same shapes
    assert jax.tree_util.tree_structure(final_cache) == \
        jax.tree_util.tree_structure(cache)


def test_decode_loop_cache_reusable_across_calls():
    """The donated cache returned by one call feeds the next (the serving
    runtime's steady-state pattern)."""
    cfg = get_config("starcoder2-3b").reduced()
    params = R.init(KEY, cfg)
    loop = ST.jit_decode_loop(ST.make_decode_loop(cfg, num_tokens=4))
    tok = jnp.ones((1, 1), jnp.int32)
    cache = R.init_cache(cfg, 1, 32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        out1, cache = loop(params, tok, cache, jnp.zeros((), jnp.int32))
        out2, cache = loop(params, out1[:, -1:], cache,
                           jnp.asarray(4, jnp.int32))
    assert out2.shape == (1, 4)
    assert int(out2.max()) < cfg.vocab
