"""Continuous-batching engine: scheduler equivalence with the simulator,
slot-step isolation, and end-to-end bit-for-bit parity with the
sequential per-token reference loop on a 200-request Poisson trace."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine as E
from repro.configs import get_config
from repro.core import batching as bt
from repro.models import registry as R
from repro.runtime import steps as ST

KEY = jax.random.PRNGKey(0)


def _cfg(kv_quant=True):
    cfg = get_config("starcoder2-3b").reduced()
    return dataclasses.replace(cfg, kv_quant=kv_quant)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = _cfg()
    return cfg, R.init(KEY, cfg)


# ---------------------------------------------------------------------------
# scheduler: one admission policy, two backends
# ---------------------------------------------------------------------------

class TestSchedulerEquivalence:
    @given(st.integers(0, 40), st.sampled_from([2000.0, 20000.0, 50000.0]))
    @settings(max_examples=12, deadline=None)
    def test_simulator_and_engine_scheduler_agree(self, seed, rate):
        """BatchQueue (simulator backend) and the engine's SlotScheduler
        replay the SAME admission decisions on the same trace — the
        policy extraction is behavior-preserving."""
        reqs = bt.poisson_arrivals(rate, 200, deadline_s=7e-3, seed=seed)
        service = bt.TABLE4_TPU.service_time
        sim = bt.BatchQueue(service, max_batch=64).run(reqs)
        policy = bt.AdmissionPolicy(service, max_batch=64)
        live = E.SlotScheduler(policy).run_virtual(reqs)
        assert [(r.start_s, r.rids) for r in sim] == \
            [(r.start_s, r.rids) for r in live]

    def test_admit_respects_capacity(self):
        policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=64,
                                    max_wait_s=0.0)
        sched = E.SlotScheduler(policy)
        for rid in range(10):
            sched.push(bt.Request(0.0, float("inf"), rid))
        got = sched.admit(0.0, capacity=3)
        assert len(got) == 3 and len(sched.pending) == 7
        assert sched.admit(0.0, capacity=0) == []


# ---------------------------------------------------------------------------
# slot step: isolation of inactive rows
# ---------------------------------------------------------------------------

def test_inactive_slot_poison_cannot_leak(dense_setup):
    """Garbage in inactive slots' cache rows (and their token inputs)
    must not change active rows' outputs or cache writes, bitwise."""
    cfg, params = dense_setup
    step = ST.jit_slot_decode_step(ST.make_slot_decode_step(cfg))
    S, smax = 4, 32
    idx = jnp.array([2, 0, 3, 1], jnp.int32)
    active = jnp.array([True, False, True, False])
    tokens = jnp.array([[5], [1], [9], [2]], jnp.int32)

    def run(cache):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            return step(params, tokens, cache, idx, active)

    clean = R.init_cache(cfg, S, smax)
    n1, c1, i1 = run(jax.tree_util.tree_map(lambda x: x.copy(), clean))
    poisoned = jax.tree_util.tree_map(
        lambda x: x.at[:, 1].set(jnp.full_like(x[:, 1], 107))
                   .at[:, 3].set(jnp.full_like(x[:, 3], -9)), clean)
    poisoned_tokens = tokens.at[1, 0].set(400).at[3, 0].set(499)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        n2, c2, i2 = step(params, poisoned_tokens, poisoned, idx, active)

    np.testing.assert_array_equal(np.asarray(n1[active]),
                                  np.asarray(n2[active]))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    for a, b in zip(jax.tree_util.tree_leaves(c1),
                    jax.tree_util.tree_leaves(c2)):
        # active rows' cache contents identical under poisoning
        np.testing.assert_array_equal(np.asarray(a[:, active]),
                                      np.asarray(b[:, active]))
    # masked sampling: inactive rows emit 0 and do not advance
    assert int(n1[1]) == 0 and int(n1[3]) == 0
    np.testing.assert_array_equal(np.asarray(i1),
                                  np.asarray(idx + active.astype(jnp.int32)))


def test_slot_rows_match_batch1_decode(dense_setup):
    """Each active slot's sample equals a batch=1 lockstep decode of the
    same request — per-row positions don't perturb the math."""
    cfg, params = dense_setup
    step = ST.jit_slot_decode_step(ST.make_slot_decode_step(cfg))
    decode = jax.jit(ST.make_decode_step(cfg))
    S, smax = 4, 32
    cache = R.init_cache(cfg, S, smax)
    idx = jnp.zeros((S,), jnp.int32)
    active = jnp.array([True, True, False, True])
    tokens = jnp.array([[5], [9], [0], [3]], jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        nxt, cache, idx = step(params, tokens, cache, idx, active)
        nxt2, _, _ = step(params, nxt[:, None], cache, idx, active)
    for row, t0 in [(0, 5), (1, 9), (3, 3)]:
        c1 = R.init_cache(cfg, 1, smax)
        l1, c1 = decode(params, {"tokens": jnp.asarray([[t0]], jnp.int32),
                                 "cache_index": jnp.asarray(0, jnp.int32)},
                        c1)
        t1 = ST.greedy_sample(l1)
        assert int(t1[0]) == int(nxt[row])
        l2, _ = decode(params, {"tokens": t1[:, None],
                                "cache_index": jnp.asarray(1, jnp.int32)},
                       c1)
        assert int(ST.greedy_sample(l2)[0]) == int(nxt2[row])


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------

def test_engine_200_requests_bit_for_bit(dense_setup):
    """Acceptance: a 200-request pseudo-Poisson trace through the live
    engine (int8 KV slots, continuous admission, zero drain barriers)
    reproduces the sequential per-token reference loop bit-for-bit and
    reports p99 + occupancy."""
    cfg, params = dense_setup
    reqs = E.synthetic_requests(200, rate_per_s=3000.0, vocab=cfg.vocab,
                                prompt_len=3, max_new_tokens=5)
    eng = E.Engine(cfg, params, num_slots=8, max_seq=16)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)

    want = E.reference_outputs(cfg, params, reqs, max_seq=16)
    assert rep.outputs() == want            # greedy tokens, bit-for-bit
    assert len(rep.results) == 200
    # no drain barrier: admissions keep landing while older requests are
    # mid-generation, and slots turn over (more requests than slots)
    assert rep.admissions_while_busy > 0
    assert rep.num_slots == 8
    assert max(rep.occupancy) <= rep.num_slots
    assert rep.p99_latency_s > 0 and rep.tokens_per_s > 0
    assert 0 < rep.mean_occupancy <= 1
    assert rep.generated_tokens == 200 * 5


def test_engine_batch_never_exceeds_bucketed_slot_count(dense_setup):
    """Property: per-tick active slots and per-admission cohorts are
    bounded by the bucketed pool size, across loads."""
    cfg, params = dense_setup
    for rate, slots in ((500.0, 3), (20000.0, 5)):
        eng = E.Engine(cfg, params, num_slots=slots, max_seq=16)
        assert eng.num_slots == ST.bucket_batch(slots)
        reqs = E.synthetic_requests(40, rate_per_s=rate, vocab=cfg.vocab,
                                    prompt_len=2, max_new_tokens=4,
                                    seed=int(rate))
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
        assert max(rep.occupancy) <= eng.num_slots
        assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                    max_seq=16)


def test_engine_slot_reuse_after_retirement(dense_setup):
    """More requests than slots forces retire-then-reuse; results must
    still be exact (stale cache rows are invisible past the frontier)."""
    cfg, params = dense_setup
    reqs = E.synthetic_requests(12, rate_per_s=1e6, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=6)
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                max_seq=16)
    # 12 requests through a 2-slot pool: every slot served >= 1 tenant
    assert {r.slot for r in rep.results} == {0, 1}


def test_engine_fp_cache_and_wall_clock(dense_setup):
    """fp16-free path: bf16 KV cache engine + wall clock returns the same
    outputs as the virtual clock (timing never leaks into tokens)."""
    cfg = _cfg(kv_quant=False)
    params = R.init(KEY, cfg)
    reqs = E.synthetic_requests(10, rate_per_s=5000.0, vocab=cfg.vocab,
                                prompt_len=3, max_new_tokens=4)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16)
    a = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    b = eng.serve(reqs, clock="wall")
    assert a.outputs() == b.outputs()
    assert a.outputs() == E.reference_outputs(cfg, params, reqs,
                                              max_seq=16)


def test_retired_mid_prefill_never_leaks_negative_ttft(dense_setup):
    """Regression (first_token_s = -1.0 sentinel): a request retired on a
    deadline miss BEFORE emitting any token must not poison the ttft
    aggregates with a negative value — they are computed only over
    requests that actually emitted."""
    cfg, params = dense_setup
    reqs = [
        # deadline passes at tick 3 of an 8-token prefill: dropped with
        # the sentinel still in place
        E.EngineRequest(rid=0, prompt=(1, 2, 3, 4, 5, 6, 7, 8),
                        max_new_tokens=4, deadline_s=2.5e-3),
        E.EngineRequest(rid=1, prompt=(3, 4), max_new_tokens=4),
        # already expired on arrival: retired at admission, before ever
        # taking a slot (no prime/prefill dispatch is wasted on it)
        E.EngineRequest(rid=2, prompt=(5,), max_new_tokens=2,
                        deadline_s=-1.0),
    ]
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3,
                    drop_missed_deadlines=True)
    by_rid = {r.rid: r for r in rep.results}
    assert rep.dropped == 2
    assert by_rid[0].dropped and not by_rid[0].emitted
    assert by_rid[0].tokens == [] and by_rid[0].first_token_s == -1.0
    assert by_rid[2].dropped and by_rid[2].slot == -1   # never admitted
    # the sentinel never leaks: aggregates are >= 0 and equal the sole
    # emitting request's ttft
    assert rep.mean_ttft_s >= 0.0 and rep.p99_ttft_s >= 0.0
    assert rep.mean_ttft_s == pytest.approx(by_rid[1].ttft_s)
    assert rep.p99_ttft_s == pytest.approx(by_rid[1].ttft_s)
    # the surviving request's tokens are untouched by its neighbor's drop
    assert by_rid[1].tokens == E.reference_outputs(
        cfg, params, [reqs[1]], max_seq=16)[1]
    # dropped requests do not enter the completion-latency percentile
    assert rep.p99_latency_s == pytest.approx(by_rid[1].latency_s)


def test_engine_temperature_requires_rng(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="rng"):
        E.Engine(cfg, params, num_slots=2, max_seq=16, temperature=0.5)


def test_engine_rejects_oversized_request(dense_setup):
    cfg, params = dense_setup
    eng = E.Engine(cfg, params, num_slots=2, max_seq=8)
    assert eng.max_seq == 16            # rounds up to a 16-aligned cache
    reqs = [E.EngineRequest(rid=0, prompt=(1, 2, 3, 4), max_new_tokens=16)]
    with pytest.raises(ValueError, match="cache positions"):
        eng.serve(reqs, clock="virtual")
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.serve([E.EngineRequest(rid=0, prompt=(1,), max_new_tokens=0)],
                  clock="virtual")


def test_engine_warmup_does_not_change_outputs(dense_setup):
    cfg, params = dense_setup
    reqs = E.synthetic_requests(6, rate_per_s=5000.0, vocab=cfg.vocab,
                                prompt_len=3, max_new_tokens=4)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16)
    eng.warmup()
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                max_seq=16)


# ---------------------------------------------------------------------------
# all token-only decode families through the same slot engine
# ---------------------------------------------------------------------------

FAMILY_ARCHS = ["qwen2-moe-a2.7b", "mamba2-1.3b", "recurrentgemma-9b"]


@pytest.fixture(scope="module", params=FAMILY_ARCHS)
def family_setup(request):
    cfg = get_config(request.param).reduced()
    return cfg, R.init(KEY, cfg)


def test_engine_family_bit_for_bit(family_setup):
    """Acceptance: moe/ssm/hybrid registry configs serve through the slot
    engine with outputs bit-for-bit equal to the sequential per-token
    reference, through slot reuse (more requests than slots)."""
    cfg, params = family_setup
    reqs = E.synthetic_requests(16, rate_per_s=3000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=4)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                max_seq=16)
    assert len(rep.results) == 16
    assert rep.admissions_while_busy > 0     # continuous, no drain barrier
    assert {r.slot for r in rep.results} == set(range(4))  # reuse happened


def test_recurrent_state_isolated_from_inactive_rows(family_setup):
    """The recurrent families' slot contract: poisoned state in inactive
    rows never leaks into active rows, inactive rows' state is frozen
    bitwise, and a reused row is scrubbed by the reset-at-position-0
    rule (so the poison also cannot survive into a new tenancy)."""
    cfg, params = family_setup
    step = ST.jit_slot_decode_step(ST.make_slot_decode_step(cfg))
    S, smax = 4, 32
    axes = R.cache_batch_axes(cfg, R.init_cache(cfg, S, smax))
    idx = jnp.array([2, 0, 3, 1], jnp.int32)
    active = jnp.array([True, False, True, False])
    tokens = jnp.array([[5], [1], [9], [2]], jnp.int32)

    def poison_rows(x, axis):
        x = jnp.moveaxis(x, axis, 0)
        x = x.at[1].set(jnp.full_like(x[1], 107))
        x = x.at[3].set(jnp.full_like(x[3], -9))
        return jnp.moveaxis(x, 0, axis)

    def run(cache):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            return step(params, tokens, cache, idx, active)

    # warm the state so rows differ from init_cache zeros (makes the
    # freeze check meaningful)
    cache0 = R.init_cache(cfg, S, smax)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        _, cache0, _ = step(params, tokens, cache0,
                            jnp.zeros((S,), jnp.int32),
                            jnp.ones((S,), bool))
    n1, c1, i1 = run(jax.tree_util.tree_map(lambda x: x.copy(), cache0))
    poisoned = {k: poison_rows(v, axes[k]) for k, v in cache0.items()}
    # snapshot before run(): the jitted step donates its cache argument
    poisoned_np = {k: np.asarray(v) for k, v in poisoned.items()}
    n2, c2, i2 = run(poisoned)

    np.testing.assert_array_equal(np.asarray(n1[active]),
                                  np.asarray(n2[active]))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    for k in c1:
        a = np.moveaxis(np.asarray(c1[k]), axes[k], 0)
        b = np.moveaxis(np.asarray(c2[k]), axes[k], 0)
        # active rows' cache identical under poisoning of inactive rows
        np.testing.assert_array_equal(a[np.asarray(active)],
                                      b[np.asarray(active)])
        # inactive rows' poison is frozen, not half-updated
        pb = np.moveaxis(poisoned_np[k], axes[k], 0)
        if k in ("k", "v", "k_scale", "v_scale"):
            continue                         # positional: masked on read
        np.testing.assert_array_equal(b[1], pb[1])
        np.testing.assert_array_equal(b[3], pb[3])
    # masked sampling: inactive rows emit 0 and do not advance
    assert int(n1[1]) == 0 and int(n1[3]) == 0
    np.testing.assert_array_equal(np.asarray(i1),
                                  np.asarray(idx + active.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_chunked_prefill_bit_for_bit_across_buckets(dense_setup, chunk):
    """Chunked prefill == per-token prefill, bit-for-bit, for every chunk
    bucket (including remainders masked inside a padded bucket), and it
    cuts admission-to-first-token ticks."""
    cfg, params = dense_setup
    reqs = E.synthetic_requests(10, rate_per_s=3000.0, vocab=cfg.vocab,
                                prompt_len=11, max_new_tokens=3)
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)
    plain = E.Engine(cfg, params, num_slots=4, max_seq=16)
    rep0 = plain.serve(reqs, clock="virtual", tick_s=1e-3)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                   prefill_chunk=chunk)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep0.outputs() == want
    assert rep.outputs() == want
    # under a constant virtual tick, ttft is tick-exact: prompt_len=11 ->
    # per-token pays 11 ticks, chunked pays ceil(10/chunk) (the final
    # chunk tick doubles as the slot's first fused tick)
    tick = 1e-3
    assert abs(rep0.mean_ttft_s - 11 * tick) < 1e-9
    want_ticks = -(-10 // chunk)
    assert abs(rep.mean_ttft_s - want_ticks * tick) < 1e-9
    assert rep.mean_ttft_s < rep0.mean_ttft_s
    assert rep.ticks < rep0.ticks


def test_chunked_prefill_families(family_setup):
    """Chunked prefill stays bit-for-bit for the recurrent and moe
    families (state chunks written by the scan-over-decode step)."""
    cfg, params = family_setup
    reqs = E.synthetic_requests(8, rate_per_s=3000.0, vocab=cfg.vocab,
                                prompt_len=7, max_new_tokens=3)
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16, prefill_chunk=4)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                max_seq=16)
    assert rep.prefill_chunk == 4


def test_chunked_prefill_single_token_prompt(dense_setup):
    """prompt_len=1 has no teacher-forced prefix: the chunk path must
    degrade to the plain admission path."""
    cfg, params = dense_setup
    reqs = [E.EngineRequest(rid=0, prompt=(9,), max_new_tokens=4)]
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16, prefill_chunk=8)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                max_seq=16)


# ---------------------------------------------------------------------------
# temperature sampling in the engine
# ---------------------------------------------------------------------------

def test_engine_temperature_matches_decode_loop(dense_setup):
    """A single request through the engine at temperature t reproduces
    make_decode_loop's fold_in(rng, position) draws bit-for-bit — the
    ported key schedule, not a lookalike."""
    cfg, params = dense_setup
    rng = jax.random.PRNGKey(123)
    n_tok, temp = 6, 0.8
    loop = ST.jit_decode_loop(
        ST.make_decode_loop(cfg, num_tokens=n_tok, temperature=temp))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        want, _ = loop(params, jnp.asarray([[7]], jnp.int32),
                       R.init_cache(cfg, 1, 16), jnp.zeros((), jnp.int32),
                       rng)
    reqs = [E.EngineRequest(rid=0, prompt=(7,), max_new_tokens=n_tok)]
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                   temperature=temp, rng=rng)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs()[0] == np.asarray(want)[0].tolist()


def test_engine_temperature_multi_request_reference_parity(dense_setup):
    """Many interleaved sampled requests (with chunked prefill) still
    match the sequential reference under the shared key schedule, and
    the draws are rng-determined (same rng -> same stream, different
    rng -> different)."""
    cfg, params = dense_setup
    rng = jax.random.PRNGKey(5)
    reqs = E.synthetic_requests(12, rate_per_s=3000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=4)
    want = E.reference_outputs(cfg, params, reqs, max_seq=16,
                               temperature=0.9, rng=rng)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16, temperature=0.9,
                   rng=rng, prefill_chunk=2)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == want
    again = E.Engine(cfg, params, num_slots=4, max_seq=16,
                     temperature=0.9, rng=rng)
    assert again.serve(reqs, clock="virtual",
                       tick_s=1e-3).outputs() == want
    other = E.Engine(cfg, params, num_slots=4, max_seq=16,
                     temperature=0.9, rng=jax.random.PRNGKey(99))
    assert other.serve(reqs, clock="virtual",
                       tick_s=1e-3).outputs() != want


# ---------------------------------------------------------------------------
# encdec/vlm: per-slot primed cross-K/V through the same slot engine
# ---------------------------------------------------------------------------

PRIME_ARCHS = ["whisper-medium", "llama-3.2-vision-90b"]


@pytest.fixture(scope="module", params=PRIME_ARCHS)
def prime_setup(request):
    cfg = get_config(request.param).reduced()
    return cfg, R.init(KEY, cfg)


def _prime_requests(cfg, n, **kw):
    kw.setdefault("rate_per_s", 3000.0)
    return E.synthetic_requests(
        n, vocab=cfg.vocab, source_shape=R.source_shape(cfg), **kw)


def test_engine_prime_family_200_requests_bit_for_bit(prime_setup):
    """Acceptance: encdec/vlm serve LIVE through the slot engine (no
    simulator fallback) — a 200-request pseudo-Poisson trace with
    per-request sources of varying length, through slot reuse,
    reproduces the sequential per-token reference bit-for-bit."""
    cfg, params = prime_setup
    reqs = _prime_requests(cfg, 200, prompt_len=3, max_new_tokens=4)
    eng = E.Engine(cfg, params, num_slots=8, max_seq=16)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                max_seq=16)
    assert len(rep.results) == 200
    assert rep.admissions_while_busy > 0     # continuous, no drain barrier
    assert {r.slot for r in rep.results} == set(range(8))  # reuse happened


def test_engine_prime_family_chunked_prefill(prime_setup):
    """Chunked prefill composes with the prime dispatch: the chunk step
    slices a slot row whose cross-K/V was already primed at admission,
    so outputs stay bit-for-bit."""
    cfg, params = prime_setup
    reqs = _prime_requests(cfg, 10, prompt_len=7, max_new_tokens=3)
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)
    eng = E.Engine(cfg, params, num_slots=4, max_seq=16, prefill_chunk=4)
    rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
    assert rep.outputs() == want


def test_engine_prime_family_requires_source(prime_setup):
    """encdec/vlm requests must carry per-request source embeddings of a
    legal shape; the engine validates before admitting anything."""
    cfg, params = prime_setup
    eng = E.Engine(cfg, params, num_slots=2, max_seq=16)
    with pytest.raises(ValueError, match="source"):
        eng.serve([E.EngineRequest(rid=0, prompt=(1, 2), max_new_tokens=2)],
                  clock="virtual")
    too_long = np.zeros((R.source_len(cfg) + 1, cfg.d_model), np.float32)
    with pytest.raises(ValueError, match="source length"):
        eng.serve([E.EngineRequest(rid=0, prompt=(1, 2), max_new_tokens=2,
                                   source=too_long)], clock="virtual")


def test_primed_cross_kv_isolated_and_scrubbed_on_reuse(prime_setup):
    """The prime contract, mirroring the recurrent-state scrub test:
    (a) poisoned cross-K/V in inactive rows (a retired tenant's
    leftovers) never changes active rows' outputs or self-cache writes;
    (b) poison past an active row's own xlen frontier is invisible;
    (c) decode never writes cross state (poison is frozen bitwise);
    (d) re-priming a poisoned row — slot reuse — fully overwrites it:
    the new tenant decodes exactly as in a fresh pool."""
    cfg, params = prime_setup
    step = ST.jit_slot_decode_step(ST.make_slot_decode_step(cfg))
    prime = jax.jit(ST.make_prime_step(cfg))
    S, smax = 4, 32
    src_max = R.source_len(cfg)
    axes = R.cache_batch_axes(cfg, R.init_cache(cfg, S, smax))

    def src_for(seed, n):
        g = np.random.default_rng(seed)
        buf = np.zeros((1, src_max, cfg.d_model), np.float32)
        buf[0, :n] = g.standard_normal((n, cfg.d_model)).astype(np.float32)
        return jnp.asarray(buf, jnp.bfloat16)

    n0, n2 = src_max, max(1, src_max - 2)
    cache = R.init_cache(cfg, S, smax)
    cache = prime(params, src_for(7, n0), cache,
                  jnp.asarray(0, jnp.int32), jnp.asarray(n0, jnp.int32))
    cache = prime(params, src_for(8, n2), cache,
                  jnp.asarray(2, jnp.int32), jnp.asarray(n2, jnp.int32))
    idx = jnp.array([1, 0, 2, 1], jnp.int32)
    active = jnp.array([True, False, True, False])
    tokens = jnp.array([[5], [1], [9], [2]], jnp.int32)

    def run(c):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            return step(params, tokens,
                        jax.tree_util.tree_map(lambda x: x.copy(), c),
                        idx, active)

    n1, c1, i1 = run(cache)

    poisoned = {k: np.array(v) for k, v in cache.items()}
    for leaf in ("xk", "xv"):
        m = np.moveaxis(poisoned[leaf], axes[leaf], 0)
        m[1] = 107.0                        # dead rows: whole cross state
        m[3] = -9.0
        m[2][:, n2:] = 55.0                 # active short row: masked tail
    poisoned["xlen"][1] = 9999
    poisoned["xlen"][3] = -5
    poisoned = {k: jnp.asarray(v) for k, v in poisoned.items()}
    poisoned_np = {k: np.asarray(v) for k, v in poisoned.items()}
    n2_, c2, i2 = run(poisoned)

    np.testing.assert_array_equal(np.asarray(n1[active]),
                                  np.asarray(n2_[active]))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    for k in c1:
        if k in ("xk", "xv", "xlen"):
            # (c) cross state is a static operand: returned bitwise as
            # passed in, poison and all
            np.testing.assert_array_equal(np.asarray(c2[k]),
                                          poisoned_np[k])
            continue
        a = np.moveaxis(np.asarray(c1[k]), axes[k], 0)
        b = np.moveaxis(np.asarray(c2[k]), axes[k], 0)
        np.testing.assert_array_equal(a[np.asarray(active)],
                                      b[np.asarray(active)])

    # (d) slot reuse: re-prime the poisoned row 1 and decode it from
    # position 0 — must equal the same tenant in a fresh pool
    nB = max(1, src_max - 1)
    srcB = src_for(9, nB)
    reused = prime(params, srcB, c2,
                   jnp.asarray(1, jnp.int32), jnp.asarray(nB, jnp.int32))
    fresh = prime(params, srcB, R.init_cache(cfg, S, smax),
                  jnp.asarray(1, jnp.int32), jnp.asarray(nB, jnp.int32))
    tok2 = jnp.array([[5], [7], [9], [2]], jnp.int32)
    only1 = jnp.array([False, True, False, False])
    zero = jnp.zeros((S,), jnp.int32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        nr, _, _ = step(params, tok2,
                        jax.tree_util.tree_map(lambda x: x.copy(), reused),
                        zero, only1)
        nf, _, _ = step(params, tok2,
                        jax.tree_util.tree_map(lambda x: x.copy(), fresh),
                        zero, only1)
    assert int(nr[1]) == int(nf[1])
