"""Multi-model multiplexing: the cross-model differential harness.

One ``Engine(models={...})`` serves interleaved two-model traces; the
gate is that every model lane's outputs are BIT-FOR-BIT the outputs of a
dedicated single-model engine serving only that lane's requests — for
every family pair from {dense, moe, encdec}, greedy AND sampled, with
the paged KV cache, preemption, and fault injection in the loop.  Plus:
cross-model poison isolation (decode-contract rule 8), (model, class)
quota invariants property-tested against the PR-7 single-model
semantics, and golden-trace regressions pinning that the new ``model=``
/ ``models=`` trace knobs move nothing when unset."""
import collections
import dataclasses
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from benchmarks import traces as TR
from repro import engine as E
from repro.configs import get_config
from repro.core import batching as bt
from repro.models import registry as R

SAMPLE_RNG = jax.random.PRNGKey(5)

# every family pair from {dense, moe, encdec}
FAMILIES = {"dense": ("starcoder2-3b", 0),
            "moe": ("qwen2-moe-a2.7b", 1),
            "encdec": ("whisper-medium", 2)}
PAIRS = [("dense", "moe"), ("dense", "encdec"), ("moe", "encdec")]

# one engine geometry for the whole module: paged, tight per-lane block
# pools (13 blocks = 3 full 16-token rows + trash), 4 leased slots
ENGINE_KW = dict(num_slots=4, max_seq=16, prefill_chunk=4,
                 block_size=4, num_blocks=13)


@pytest.fixture(scope="module")
def families():
    out = {}
    for fam, (arch, seed) in FAMILIES.items():
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  kv_quant=True)
        out[fam] = (cfg, R.init(jax.random.PRNGKey(seed), cfg))
    return out


def _trace(tag, cfg, n, *, seed, rid_offset=0):
    """One lane's sub-trace: model-tagged, mixed SLO classes, inf
    deadlines (nothing drops — parity must hold on every request),
    sources attached for prime families, rids offset so the merged
    two-model trace keys uniquely."""
    reqs = E.synthetic_requests(
        n, rate_per_s=2000.0, vocab=cfg.vocab, prompt_len=4,
        max_new_tokens=5, seed=seed, model=tag,
        priority=lambda rid: "interactive" if rid % 3 else "batch",
        source_shape=((R.source_len(cfg), cfg.d_model)
                      if R.needs_prime(cfg) else None))
    return [dataclasses.replace(r, rid=r.rid + rid_offset) for r in reqs]


def _merged_pair(families, fa, fb, n_each=100):
    """A 2*n_each-request interleaved two-model trace plus each lane's
    (cfg, params)."""
    ca, pa = families[fa]
    cb, pb = families[fb]
    ta = _trace("a", ca, n_each, seed=11)
    tb = _trace("b", cb, n_each, seed=22, rid_offset=1000)
    merged = sorted(ta + tb, key=lambda r: r.arrival_s)
    return merged, {"a": (ca, pa), "b": (cb, pb)}


def _strip(reqs):
    return [dataclasses.replace(r, model=None) for r in reqs]


# ---------------------------------------------------------------------------
# the differential harness: multiplexed == dedicated, bitwise
# ---------------------------------------------------------------------------

class TestDifferential:
    @pytest.mark.parametrize("fa,fb", PAIRS)
    @pytest.mark.parametrize("temperature", [0.0, 0.7],
                             ids=["greedy", "sampled"])
    def test_multiplexed_matches_dedicated(self, families, fa, fb,
                                           temperature):
        """For every family pair, a 200-request interleaved two-model
        trace served multiplexed (paged KV, tight blocks, preemption,
        mixed SLO classes) produces per-model outputs bit-for-bit equal
        to dedicated single-model engines serving each lane's own
        sub-trace.  Holds sampled too: the position-derived key schedule
        makes tokens independent of cross-model admission timing."""
        merged, lanes = _merged_pair(families, fa, fb)
        kw = dict(ENGINE_KW, temperature=temperature,
                  rng=SAMPLE_RNG if temperature > 0 else None)
        mux = E.Engine(models=lanes, **kw)
        mrep = mux.serve(merged, clock="virtual", tick_s=1e-3,
                         preemption=True)
        assert len(mrep.results) == len(merged)
        assert all(r.status == "ok" for r in mrep.results)
        assert mrep.leaked_blocks == 0
        for tag, (cfg, params) in lanes.items():
            ded = E.Engine(cfg, params, **kw)
            sub = _strip([r for r in merged if r.model == tag])
            drep = ded.serve(sub, clock="virtual", tick_s=1e-3,
                             preemption=True)
            assert mrep.outputs_for(tag) == drep.outputs(), \
                f"lane {tag} ({fa if tag == 'a' else fb}) diverged"
        # per-model report partitions: lanes' outputs cover everything
        got = dict(mrep.outputs_for("a"))
        got.update(mrep.outputs_for("b"))
        assert got == mrep.outputs()
        assert set(mrep.model_mean_occupancy) == {"a", "b"}

    def test_chaos_arm(self, families):
        """The satellite chaos gate: a bursty mixed-model two-class
        trace with a seeded cross-lane fault plan AND forced preemption
        on under-provisioned per-lane pools — zero leaked blocks, no
        request lost, and every non-failed output exactly its own
        lane's sequential reference."""
        merged, lanes = _merged_pair(families, "dense", "moe")
        want = {tag: E.reference_outputs(
                    cfg, params,
                    _strip([r for r in merged if r.model == tag]),
                    max_seq=16)
                for tag, (cfg, params) in lanes.items()}
        eng = E.Engine(models=lanes, **ENGINE_KW)
        plan = E.FaultPlan.random(seed=42, n_faults=12, max_tick=400,
                                  num_slots=8)   # global ids, 2 lanes
        rep = eng.serve(merged, clock="virtual", tick_s=1e-3,
                        preemption=True, fault_plan=plan)
        assert len(rep.results) == len(merged)
        assert rep.leaked_blocks == 0
        assert rep.preempted > 0
        assert plan.fired
        bad = [r.rid for r in rep.results
               if r.status == "ok" and r.tokens != want[r.model][r.rid]]
        assert not bad, f"cross-model state leak: rids {bad[:8]}"

    def test_prefix_keys_are_model_fingerprinted(self, families):
        """The same token prompt hashes to DIFFERENT prefix-key chains
        on different lanes (and to the untagged single-model chain on
        neither), so paged sharing cannot cross models even before the
        lane-private BlockPools make it structurally impossible."""
        merged, lanes = _merged_pair(families, "dense", "moe", n_each=4)
        eng = E.Engine(models=lanes, **ENGINE_KW)
        single = E.Engine(*lanes["a"], **ENGINE_KW)
        probe = _strip([r for r in merged
                        if r.model == "a" and len(r.prompt) >= 4])[0]
        ka = eng.lanes["a"]._prefix_keys(probe)
        kb = eng.lanes["b"]._prefix_keys(probe)
        k0 = single.lanes[None]._prefix_keys(probe)
        assert ka and kb and k0
        assert ka != kb and ka != k0 and kb != k0


# ---------------------------------------------------------------------------
# cross-model poison: one lane's corruption is invisible to the other
# ---------------------------------------------------------------------------

class TestCrossModelPoison:
    def test_poisoned_lane_cannot_perturb_the_other(self, families):
        """Corrupt model A's fused dispatch so every sample is the -1
        sentinel: A's requests burn their retry budgets and retire as
        typed ``failed`` — and model B's outputs stay bitwise identical
        to the clean run.  Fault isolation is per-lane, not per-engine."""
        merged, lanes = _merged_pair(families, "dense", "moe", n_each=24)
        clean = E.Engine(models=lanes, **ENGINE_KW)
        baseline = clean.serve(merged, clock="virtual", tick_s=1e-3,
                               preemption=True).outputs_for("b")

        eng = E.Engine(models=lanes, **ENGINE_KW)
        orig = eng.lanes["a"]._fused

        def poisoned(tokens, cache, index, active):
            nxt, cache, new_index = orig(tokens, cache, index, active)
            return jnp.full_like(nxt, -1), cache, new_index

        eng.lanes["a"]._fused = poisoned
        rep = eng.serve(merged, clock="virtual", tick_s=1e-3,
                        preemption=True, max_retries=1)
        assert len(rep.results) == len(merged)
        a_res = [r for r in rep.results if r.model == "a"]
        assert a_res and all(r.status == "failed" for r in a_res)
        b_res = [r for r in rep.results if r.model == "b"]
        assert all(r.status == "ok" for r in b_res)
        assert rep.outputs_for("b") == baseline
        assert rep.leaked_blocks == 0        # failed slots drain clean

    def test_nan_in_one_cache_never_reaches_the_other_lanes_step(
            self, families):
        """Decode-contract rule 8 at the step level: fill lane A's
        device cache with NaN and lane B's very next fused dispatch is
        bitwise unchanged — no leaf of one model's state is ever an
        input to another model's step."""
        merged, lanes = _merged_pair(families, "dense", "moe", n_each=4)
        e1 = E.Engine(models=lanes, **ENGINE_KW)
        e2 = E.Engine(models=lanes, **ENGINE_KW)
        e2.lanes["a"].cache = jax.tree_util.tree_map(
            lambda x: (jnp.full_like(x, jnp.nan)
                       if jnp.issubdtype(x.dtype, jnp.floating) else x),
            e2.lanes["a"].cache)
        S = e1.num_slots
        tokens = jnp.ones((S, 1), jnp.int32)
        idx = jnp.zeros((S,), jnp.int32)
        active = jnp.ones((S,), bool)
        n1, _, i1 = e1.lanes["b"]._fused(tokens, e1.lanes["b"].cache,
                                         idx, active)
        n2, _, i2 = e2.lanes["b"]._fused(tokens, e2.lanes["b"].cache,
                                         idx, active)
        np.testing.assert_array_equal(np.asarray(n1), np.asarray(n2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# (model, class) quota keys: property test + PR-7 boundary equivalence
# ---------------------------------------------------------------------------

QUOTA_CONFIGS = [
    {},                                       # uncapped
    {"batch": 2},                             # class-wide, cross-model
    {"a": 2},                                 # model-wide, cross-class
    {("a", "batch"): 1},                      # pinned intersection
    {"a": 3, "batch": 2, ("b", "interactive"): 1},   # all three kinds
]


def _meter_keys(m, c):
    """The keys one (model, class) request is metered against — the
    engine's admission loop and ``AdmissionPolicy._quota_keys`` agree."""
    return ((m, c), m, c)


class TestQuotaInvariants:
    @given(st.integers(0, 19), st.sampled_from(list(range(len(
        QUOTA_CONFIGS)))))
    @settings(max_examples=40, deadline=None)
    def test_never_exceed_and_never_barrier(self, seed, qi):
        """Drive SlotScheduler.admit with the multiplexed engine's
        ``key_fn`` through random push/admit/retire rounds: (1) no
        quota key's active count ever exceeds its quota; (2) blocked
        requests are skipped, never barriers — whenever admission
        leaves capacity unused, every request still pending is
        quota-blocked against the post-admission actives."""
        quotas = QUOTA_CONFIGS[qi]
        rng = random.Random(seed)
        policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=8,
                                    max_wait_s=0.0, class_quotas=quotas)
        sched = E.SlotScheduler(policy)
        S, rid = 6, 0
        active = []                       # (model, class) keys held
        for _ in range(12):
            for _ in range(rng.randrange(4)):
                req = E.EngineRequest(
                    rid=rid, prompt=(1,), max_new_tokens=1,
                    deadline_s=float("inf"),
                    priority=rng.choice(("interactive", "batch")),
                    model=rng.choice(("a", "b")))
                sched.push(req)
                rid += 1
            abc = collections.Counter()
            for m, c in active:
                for k in _meter_keys(m, c):
                    abc[k] += 1
            cap = S - len(active)
            got = sched.admit(0.0, cap, None, active_by_class=abc,
                              key_fn=lambda r: (r.model, r.priority))
            active.extend((r.model, r.priority) for r in got)
            assert len(active) <= S
            cnt = collections.Counter()
            for m, c in active:
                for k in _meter_keys(m, c):
                    cnt[k] += 1
            for k, q in quotas.items():
                assert cnt[k] <= q, f"quota key {k!r} over limit"
            if cap > 0 and len(got) < cap:
                for r in sched.pending:
                    keys = _meter_keys(r.model, r.priority)
                    assert any(k in quotas and cnt[k] >= quotas[k]
                               for k in keys), \
                        (f"rid {r.rid} is unblocked yet pending with "
                         f"{cap - len(got)} free slots — quota became "
                         f"a barrier")
            for i in reversed(range(len(active))):
                if rng.random() < 0.4:
                    active.pop(i)


def _pr7_decide_classes(policy, now, deadlines, next_arrival, cap,
                        costs, budget, classes, active_by_class):
    """PR-7's ``_decide_classes``, verbatim semantics: string quota keys
    only (a class meters exactly itself) and an int pool budget.  The
    boundary tests pin today's generalized tuple-key/mapping-budget code
    to this on every input the old code could see."""
    used = dict(active_by_class or {})
    sel = []
    for i, c in enumerate(classes):
        if len(sel) >= cap:
            break
        if policy.class_quotas.get(c) is not None \
                and used.get(c, 0) >= policy.class_quotas[c]:
            continue
        sel.append(i)
        used[c] = used.get(c, 0) + 1
    wait = bt.Admission(False, wait_until=(
        next_arrival if next_arrival is not None else now))
    if not sel:
        return wait
    earliest = min(deadlines[i] for i in sel)
    while len(sel) > 1 and now + policy.service_time(len(sel)) > earliest:
        sel.pop()
        earliest = min(deadlines[i] for i in sel)
    if costs is not None and budget is not None:
        while sel and sum(costs[i] for i in sel) > budget:
            sel.pop()
        if not sel:
            return wait
    can_wait = (
        len(sel) < cap and next_arrival is not None
        and next_arrival - now <= policy.max_wait_s
        and next_arrival + policy.service_time(
            min(len(sel) + 1, cap)) <= earliest)
    if can_wait:
        return bt.Admission(False, wait_until=next_arrival)
    return bt.Admission(True, batch=len(sel), picks=tuple(sel))


class TestPR7Boundary:
    @given(st.integers(0, 59))
    @settings(max_examples=60, deadline=None)
    def test_class_only_path_byte_identical_to_pr7(self, seed):
        """String-classed admission (what every PR-7 caller passes)
        through today's ``decide`` returns the exact Admission —
        including ``picks`` — the PR-7 procedure returns, across random
        quotas, deadlines, costs/budget, and wait windows."""
        rng = random.Random(seed)
        policy = bt.AdmissionPolicy(
            lambda b: 5e-4 * b, max_batch=8, max_wait_s=2e-3,
            class_quotas=rng.choice([{}, {"batch": 2},
                                     {"interactive": 3},
                                     {"batch": 1, "interactive": 4}]))
        n = rng.randrange(1, 10)
        now = rng.random()
        deadlines = [now + rng.uniform(1e-4, 2e-2) for _ in range(n)]
        classes = [rng.choice(("interactive", "batch"))
                   for _ in range(n)]
        abc = {c: rng.randrange(0, 3)
               for c in ("interactive", "batch")}
        use_budget = rng.random() < 0.5
        costs = [rng.randrange(1, 4) for _ in range(n)] \
            if use_budget else None
        budget = rng.randrange(0, 12) if use_budget else None
        next_arrival = rng.choice(
            [None, now + 5e-4, now + 5e-3])
        cap = rng.randrange(1, 9)
        got = policy.decide(now, deadlines, next_arrival,
                            capacity=cap, costs=costs, budget=budget,
                            classes=classes, active_by_class=abc)
        want = _pr7_decide_classes(
            policy, now, deadlines, next_arrival,
            min(cap, policy.max_batch), costs, budget, classes, abc)
        assert got == want

    @given(st.integers(0, 29))
    @settings(max_examples=30, deadline=None)
    def test_no_quota_tuple_path_reduces_to_legacy_prefix(self, seed):
        """With no quotas configured, tuple-classed admission (the
        multiplexed key_fn path) on a deadline-sorted queue picks
        exactly the legacy prefix cohort — same launch/batch/wait, and
        ``picks`` is literally ``range(batch)``."""
        rng = random.Random(seed)
        policy = bt.AdmissionPolicy(lambda b: 5e-4 * b, max_batch=8,
                                    max_wait_s=2e-3)
        n = rng.randrange(1, 10)
        now = rng.random()
        deadlines = sorted(now + rng.uniform(1e-4, 2e-2)
                           for _ in range(n))
        classes = [(rng.choice(("a", "b")), "interactive")
                   for _ in range(n)]
        use_budget = rng.random() < 0.5
        costs = [rng.randrange(1, 4) for _ in range(n)] \
            if use_budget else None
        budget = rng.randrange(1, 12) if use_budget else None
        next_arrival = rng.choice([None, now + 5e-4, now + 5e-3])
        cap = rng.randrange(1, 9)
        legacy = policy.decide(now, deadlines, next_arrival,
                               capacity=cap, costs=costs, budget=budget)
        tupled = policy.decide(now, deadlines, next_arrival,
                               capacity=cap, costs=costs, budget=budget,
                               classes=classes, active_by_class={})
        assert tupled.launch == legacy.launch
        assert tupled.batch == legacy.batch
        assert tupled.wait_until == legacy.wait_until
        if tupled.launch:
            assert tupled.picks == tuple(range(legacy.batch))

    def test_mapping_budget_sheds_only_the_starved_model(self):
        """A per-model budget mapping: the model with zero free blocks
        sheds its whole cohort, the other model admits through it —
        memory pressure on one lane never barriers the rest."""
        policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=8,
                                    max_wait_s=0.0)
        now = 0.0
        classes = [("b", "interactive"), ("a", "interactive"),
                   ("b", "interactive"), ("a", "interactive")]
        deadlines = [float("inf")] * 4
        costs = [2, 2, 2, 2]
        act = policy.decide(now, deadlines, None, capacity=4,
                            costs=costs, budget={"a": 8, "b": 0},
                            classes=classes, active_by_class={})
        assert act.launch and act.picks == (1, 3)


# ---------------------------------------------------------------------------
# per-model quota end to end: a model's lease never exceeds its cap
# ---------------------------------------------------------------------------

def test_model_quota_caps_lane_occupancy(families):
    """``class_quotas={'a': 2}`` on a multiplexed engine: lane a never
    holds more than 2 of the 4 leased slots on ANY tick, lane b is free
    to take the rest, and every request still completes."""
    ca, pa = families["dense"]
    cb, pb = families["moe"]
    # asymmetric demand: once a's short queue drains, b must be able to
    # grow past the 2 slots a's quota was reserving
    ta = _trace("a", ca, 8, seed=11)
    tb = _trace("b", cb, 32, seed=22, rid_offset=1000)
    merged = sorted(ta + tb, key=lambda r: r.arrival_s)
    lanes = {"a": (ca, pa), "b": (cb, pb)}
    policy = bt.AdmissionPolicy(lambda b: 0.0, max_batch=4,
                                max_wait_s=0.0, class_quotas={"a": 2})
    eng = E.Engine(models=lanes, num_slots=4, max_seq=16,
                   prefill_chunk=4, block_size=4, policy=policy)
    rep = eng.serve(merged, clock="virtual", tick_s=1e-3)
    assert len(rep.results) == len(merged)
    assert all(r.status == "ok" for r in rep.results)
    assert max(rep.model_occupancy["a"]) <= 2
    assert max(rep.model_occupancy["b"]) > 2   # b uses the freed lease
    assert rep.leaked_blocks == 0


# ---------------------------------------------------------------------------
# engine validation of the multi-model surface
# ---------------------------------------------------------------------------

class TestValidation:
    def test_unknown_model_tag_rejected(self, families):
        merged, lanes = _merged_pair(families, "dense", "moe", n_each=2)
        eng = E.Engine(models=lanes, **ENGINE_KW)
        bad = dataclasses.replace(merged[0], model="zzz")
        with pytest.raises(ValueError, match="not admitted"):
            eng.serve([bad])

    def test_tagged_request_rejected_on_single_model_engine(
            self, families):
        cfg, params = families["dense"]
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16)
        req = E.EngineRequest(rid=0, prompt=(1, 2), max_new_tokens=2,
                              model="a")
        with pytest.raises(ValueError, match="not admitted"):
            eng.serve([req])

    def test_constructor_surface(self, families):
        cfg, params = families["dense"]
        with pytest.raises(ValueError, match="exactly one"):
            E.Engine(cfg, params, models={"a": (cfg, params)})
        with pytest.raises(ValueError, match="exactly one"):
            E.Engine()
        with pytest.raises(ValueError, match="at least one"):
            E.Engine(models={})
        with pytest.raises(ValueError, match="non-empty string"):
            E.Engine(models={"": (cfg, params)})


# ---------------------------------------------------------------------------
# golden-trace regressions: the model knobs move nothing when unset
# ---------------------------------------------------------------------------

class TestGoldenTraces:
    def test_synthetic_requests_defaults_pinned(self):
        """Literal golden pins (computed before the ``model=`` knob
        existed): the default trace may not move by a byte."""
        reqs = E.synthetic_requests(4, rate_per_s=1000.0, vocab=97,
                                    seed=3)
        assert [r.prompt for r in reqs] == [
            (1, 4, 7, 10), (8, 11, 14, 17),
            (15, 18, 21, 24), (22, 25, 28, 31)]
        assert [r.arrival_s for r in reqs] == pytest.approx(
            [0.000271762303, 0.001057527586,
             0.001519491884, 0.002445631049], rel=1e-9)
        assert all(r.model is None for r in reqs)
        assert all(r.priority == "interactive" for r in reqs)
        untagged = E.synthetic_requests(4, rate_per_s=1000.0, vocab=97,
                                        seed=3, model=None)
        assert untagged == reqs

    def test_two_class_trace_defaults_pinned(self):
        reqs = TR.two_class_trace(4, rate_per_s=500.0, vocab=97, seed=2)
        assert [r.prompt for r in reqs] == [
            (1, 4, 7), (8, 11, 14), (15, 18, 21), (22, 25)]
        assert [r.arrival_s for r in reqs] == pytest.approx(
            [0.023625595958, 0.024091302834,
             0.024800833454, 0.039239536565], rel=1e-9)
        assert [r.priority for r in reqs] == [
            "interactive", "batch", "interactive", "interactive"]
        assert [r.max_new_tokens for r in reqs] == [6, 3, 2, 2]
        assert all(r.model is None for r in reqs)
        untagged = TR.two_class_trace(4, rate_per_s=500.0, vocab=97,
                                      seed=2, models=None)
        assert untagged == reqs

    def test_model_tagging_changes_only_model_and_vocab(self):
        """Tagged traces keep arrivals/lengths/classes of the untagged
        trace; only the tag and the per-lane vocab drawing differ."""
        base = TR.two_class_trace(12, rate_per_s=500.0, vocab=97, seed=2)
        tagged = TR.two_class_trace(12, rate_per_s=500.0, vocab=0,
                                    seed=2, models=[("a", 97), ("b", 53)])
        assert [r.arrival_s for r in tagged] == \
            [r.arrival_s for r in base]
        assert [r.priority for r in tagged] == \
            [r.priority for r in base]
        assert [r.max_new_tokens for r in tagged] == \
            [r.max_new_tokens for r in base]
        assert [r.model for r in tagged] == ["a", "b"] * 6
        for r in tagged:
            v = 97 if r.model == "a" else 53
            assert all(1 <= t < v for t in r.prompt)
        # lane a draws in the same vocab as base -> identical prompts
        assert [r.prompt for r in tagged if r.model == "a"] == \
            [r.prompt for r in base if r.rid % 2 == 0]

    def test_synthetic_model_callable(self):
        reqs = E.synthetic_requests(
            6, rate_per_s=1000.0, vocab=97,
            model=lambda rid: "a" if rid % 2 == 0 else "b")
        assert [r.model for r in reqs] == ["a", "b"] * 3
        base = E.synthetic_requests(6, rate_per_s=1000.0, vocab=97)
        assert [dataclasses.replace(r, model=None) for r in reqs] == base
