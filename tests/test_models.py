"""Per-architecture smoke tests (reduced configs) + model-level oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.qlinear import FP, W8A16, W8A8
from repro.core.quant import quantize_tree
from repro.models import registry as R

ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.random.normal(
            KEY, (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch).reduced()
        params = R.init(KEY, cfg)
        batch = _batch(cfg)
        logits = R.apply_forward(params, cfg, batch)
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_one_train_step(self, arch):
        from repro.optim import make_optimizer
        from repro.runtime import steps as ST
        cfg = get_config(arch).reduced()
        params = R.init(KEY, cfg)
        opt = make_optimizer("adamw", lr=1e-3)
        opt_state = opt.init(params)
        batch = _batch(cfg)
        batch["labels"] = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
        step = ST.make_train_step(cfg, opt)
        new_params, _, metrics = step(params, opt_state, batch, KEY)
        assert bool(jnp.isfinite(metrics["loss"]))
        # params actually changed
        delta = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
        assert max(jax.tree_util.tree_leaves(delta)) > 0

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        params = R.init(KEY, cfg)
        batch = _batch(cfg)
        cache = R.init_cache(cfg, 2, 64)
        m = R.module_for(cfg)
        if cfg.family == "encdec":
            cache = m.prime_cache(params, cache, batch["encoder_embeds"],
                                  cfg)
        if cfg.family == "vlm":
            cache = m.prime_cache(params, cache, batch["vision_embeds"], cfg)
        d = {"tokens": batch["tokens"][:, :1], "cache_index": jnp.array(0)}
        logits, new_cache = R.apply_decode(params, cfg, d, cache)
        assert logits.shape == (2, 1, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_quantized_forward_close_to_fp(self, arch):
        cfg = get_config(arch).reduced()
        params = R.init(KEY, cfg)
        batch = _batch(cfg)
        fp = R.apply_forward(params, cfg, batch).astype(jnp.float32)
        qp = quantize_tree(params, min_size=2048)
        q = R.apply_forward(qp, cfg, batch, mode=W8A16).astype(jnp.float32)
        rel = float(jnp.linalg.norm(q - fp) / (jnp.linalg.norm(fp) + 1e-9))
        assert rel < 0.15, f"{arch}: quantized deviates {rel:.3f}"


@pytest.mark.parametrize("arch", ["starcoder2-3b", "mixtral-8x22b",
                                  "recurrentgemma-9b", "mamba2-1.3b",
                                  "whisper-medium"])
def test_decode_matches_forward(arch):
    """Stepwise decode must reproduce the teacher-forced forward logits."""
    cfg = get_config(arch).reduced()
    params = R.init(KEY, cfg)
    batch = _batch(cfg, b=2, s=8)
    ref = R.apply_forward(params, cfg, batch).astype(jnp.float32)
    cache = R.init_cache(cfg, 2, 32)
    m = R.module_for(cfg)
    if cfg.family == "encdec":
        cache = m.prime_cache(params, cache, batch["encoder_embeds"], cfg)
    outs = []
    for i in range(8):
        d = {"tokens": batch["tokens"][:, i:i + 1],
             "cache_index": jnp.array(i)}
        lg, cache = R.apply_decode(params, cfg, d, cache)
        outs.append(lg[:, 0].astype(jnp.float32))
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    err = float(jnp.max(jnp.abs(dec - ref))) / scale
    assert err < 0.05, f"{arch}: decode/forward relative gap {err:.4f}"


class TestSSDOracle:
    """The chunked SSD algorithm vs a naive sequential recurrence."""

    def _naive_ssd(self, xh, dt, a_log, B, C):
        b, s, h, hd = xh.shape
        n = B.shape[-1]
        A = -jnp.exp(a_log)
        state = jnp.zeros((b, h, hd, n), jnp.float32)
        ys = []
        for t in range(s):
            a_t = jnp.exp(dt[:, t] * A[None])                  # (B,H)
            contrib = jnp.einsum("bn,bhd,bh->bhdn", B[:, t], xh[:, t],
                                 dt[:, t])
            state = a_t[..., None, None] * state + contrib
            ys.append(jnp.einsum("bn,bhdn->bhd", C[:, t], state))
        return jnp.stack(ys, axis=1)

    @pytest.mark.parametrize("s,chunk", [(16, 4), (17, 4), (8, 8), (12, 5)])
    def test_chunked_matches_naive(self, s, chunk):
        from repro.models.ssm import _ssd_chunked
        b, h, hd, n = 2, 3, 4, 5
        keys = jax.random.split(KEY, 4)
        xh = jax.random.normal(keys[0], (b, s, h, hd))
        dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, h)))
        a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
        B = jax.random.normal(keys[2], (b, s, n))
        C = jax.random.normal(keys[3], (b, s, n))
        got = _ssd_chunked(xh, dt, a_log, B, C, chunk)
        want = self._naive_ssd(xh, dt, a_log, B, C)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


class TestRGLRUOracle:
    def test_associative_scan_matches_sequential(self):
        from repro.models.rglru import init_rglru, rglru
        p = init_rglru(KEY, 16)
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 12, 16))
        y_par, last = rglru(p, x)
        # sequential via repeated single-step decode
        state = jnp.zeros((2, 16), jnp.float32)
        outs = []
        for t in range(12):
            yt, state = rglru(p, x[:, t:t + 1], state=state)
            outs.append(yt[:, 0])
        y_seq = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_par, np.float32),
                                   np.asarray(y_seq, np.float32),
                                   rtol=1e-4, atol=1e-4)


class TestMoE:
    def test_capacity_drops_are_bounded(self):
        from repro.models.moe import moe_ffn, init_moe_ffn
        cfg = get_config("qwen2-moe-a2.7b").reduced()
        p = init_moe_ffn(KEY, cfg)
        x = jax.random.normal(KEY, (2, 16, cfg.d_model))
        out = moe_ffn(p, x, cfg)
        assert out.shape == x.shape
        assert bool(jnp.isfinite(out).all())

    def test_sliding_window_mask(self):
        """Mixtral SWA: token t must not attend beyond window."""
        from repro.models.layers import _chunked_attention
        b, s, h, hd = 1, 12, 1, 4
        q = jnp.ones((b, s, h, hd))
        k = jnp.ones((b, s, h, hd))
        # one-hot values reveal which positions were attended
        v = jnp.eye(s)[None, :, None, :4 * ((s + 3) // 4)][..., :hd]
        v = jnp.broadcast_to(jnp.arange(s, dtype=jnp.float32)
                             .reshape(1, s, 1, 1), (b, s, h, hd))
        out = _chunked_attention(q, k, v, causal=True, window=4, q_block=4)
        # position 11 attends {8,9,10,11} -> mean 9.5
        assert float(out[0, 11, 0, 0]) == pytest.approx(9.5, abs=1e-3)
        # position 2 attends {0,1,2} -> mean 1.0
        assert float(out[0, 2, 0, 0]) == pytest.approx(1.0, abs=1e-3)


class TestPaperNets:
    def test_weight_counts_match_table1(self):
        from repro.configs.paper_apps import PAPER_APP_CONFIGS
        from repro.models import paper_nets as PN
        for name, cfg in PAPER_APP_CONFIGS.items():
            params = PN.init_app(KEY, cfg)
            w = PN.weight_count(params)
            assert w == pytest.approx(cfg.weights_target_m * 1e6,
                                      rel=0.20), name

    @pytest.mark.parametrize("name", ["MLP0", "LSTM1", "CNN0"])
    def test_quantized_close(self, name):
        from repro.configs.paper_apps import PAPER_APP_CONFIGS
        from repro.models import paper_nets as PN
        cfg = PAPER_APP_CONFIGS[name]
        params = PN.init_app(KEY, cfg)
        x = PN.app_input(cfg, batch=4)
        y = PN.apply_app(params, cfg, x).astype(jnp.float32)
        qp = quantize_tree(params, min_size=1024)
        yq = PN.apply_app(qp, cfg, x, mode=W8A16).astype(jnp.float32)
        rel = float(jnp.linalg.norm(yq - y) / (jnp.linalg.norm(y) + 1e-9))
        assert rel < 0.1
