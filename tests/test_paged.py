"""Paged KV cache: BlockPool invariants (property-tested), typed
admission rejection, memory-aware admission, shared-prefix reuse, and
the poison test proving a recycled block's stale bytes are never read.

The engine-level parity tests here are the paged analogue of
test_engine.py's bit-for-bit discipline: the paged engine must produce
EXACTLY the sequential reference's outputs while slots AND blocks are
reused across tenants and prefix blocks are shared refcounted between
concurrently-live requests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine as E
from repro.configs import get_config
from repro.core import batching as bt
from repro.core.qlinear import FP
from repro.models import registry as R
from repro.runtime import steps as ST

KEY = jax.random.PRNGKey(0)


def _cfg(kv_quant=True):
    cfg = get_config("starcoder2-3b").reduced()
    return dataclasses.replace(cfg, kv_quant=kv_quant)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = _cfg()
    return cfg, R.init(KEY, cfg)


# ---------------------------------------------------------------------------
# BlockPool invariants
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_trash_block_reserved(self):
        pool = E.BlockPool(4, 2)
        bids = [pool.alloc() for _ in range(3)]
        assert 0 not in bids and sorted(bids) == [1, 2, 3]

    @given(st.integers(2, 9))
    @settings(max_examples=8, deadline=None)
    def test_alloc_free_roundtrip_restores_pool(self, num_blocks):
        """Allocating the whole pool and releasing it restores the free
        list exactly; a fresh alloc succeeds again."""
        pool = E.BlockPool(num_blocks, 4)
        bids = [pool.alloc() for _ in range(num_blocks - 1)]
        assert pool.free_blocks == 0
        assert pool.used_blocks == num_blocks - 1
        for b in bids:
            pool.release(b)
        assert pool.free_blocks == num_blocks - 1
        assert all(rc == 0 for rc in pool.refcounts)
        assert pool.alloc() > 0

    @given(st.integers(2, 6))
    @settings(max_examples=6, deadline=None)
    def test_exhaustion_raises_without_corrupting(self, num_blocks):
        """An over-allocation raises; the pool state is untouched (no
        refcount moved, nothing popped)."""
        pool = E.BlockPool(num_blocks, 4)
        bids = [pool.alloc() for _ in range(num_blocks - 1)]
        before = list(pool.refcounts)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.alloc()
        assert pool.refcounts == before
        for b in bids:
            pool.release(b)
        assert pool.free_blocks == num_blocks - 1

    def test_refcount_never_negative(self):
        pool = E.BlockPool(4, 2)
        b = pool.alloc()
        pool.release(b)
        with pytest.raises(RuntimeError, match="never go negative"):
            pool.release(b)               # already free
        with pytest.raises(RuntimeError):
            pool.release(0)               # the trash block has no refs
        with pytest.raises(RuntimeError):
            pool.ref(b)                   # dead block cannot gain refs

    def test_sharing_lifecycle(self):
        """register -> lookup -> ref; the LAST release evicts the hash
        entry, so a recycled block can never be found by lookup."""
        pool = E.BlockPool(4, 2)
        b = pool.alloc()
        key = ((), (5, 6))
        pool.register(key, b)
        assert pool.lookup(key) == b
        pool.ref(b)                       # second tenant
        pool.release(b)                   # first tenant retires
        assert pool.lookup(key) == b      # still live: one ref left
        pool.release(b)                   # last ref
        assert pool.lookup(key) is None
        with pytest.raises(RuntimeError, match="dead"):
            pool.register(key, b)         # dead blocks cannot publish
        b2 = pool.alloc()                 # recycled
        assert pool.refcounts[b2] == 1

    @given(st.integers(0, 5))
    @settings(max_examples=6, deadline=None)
    def test_random_ops_keep_invariants(self, seed):
        """Any interleaving of alloc/ref/release keeps refcounts >= 0 and
        held + free == usable blocks."""
        rng = np.random.default_rng(seed)
        pool = E.BlockPool(6, 4)
        live = []                         # one entry per outstanding ref
        for _ in range(60):
            op = rng.integers(0, 3)
            if op == 0 and pool.free_blocks:
                live.append(pool.alloc())
            elif op == 1 and live:
                bid = live[rng.integers(len(live))]
                pool.ref(bid)
                live.append(bid)
            elif op == 2 and live:
                pool.release(live.pop(rng.integers(len(live))))
            assert all(rc >= 0 for rc in pool.refcounts)
            held = sum(1 for rc in pool.refcounts if rc > 0)
            assert held + pool.free_blocks == pool.num_blocks - 1


# ---------------------------------------------------------------------------
# typed admission rejection
# ---------------------------------------------------------------------------

class TestRequestTooLong:
    def test_is_a_value_error(self):
        assert issubclass(E.RequestTooLong, ValueError)

    def test_slot_pool_validates_max_seq(self):
        pool = E.SlotPool(2, max_seq=8)
        with pytest.raises(E.RequestTooLong, match="cache positions"):
            pool.alloc(0, tuple(range(1, 7)), 4, now=0.0, arrival_s=0.0)
        # within budget: fine
        st_ = pool.alloc(1, (1, 2, 3), 5, now=0.0, arrival_s=0.0)
        assert st_.rid == 1

    def test_engine_rejects_oversized_request(self, dense_setup):
        cfg, params = dense_setup
        eng = E.Engine(cfg, params, num_slots=2, max_seq=16)
        bad = [E.EngineRequest(rid=0, prompt=tuple(range(1, 15)),
                               max_new_tokens=8)]
        with pytest.raises(E.RequestTooLong, match="cache positions"):
            eng.serve(bad)

    def test_paged_engine_rejects_unservable_block_demand(self,
                                                          dense_setup):
        """A request needing more blocks than the whole pool holds can
        never be admitted (it would wait forever): typed rejection up
        front, not a hang."""
        cfg, params = dense_setup
        eng = E.Engine(cfg, params, num_slots=2, max_seq=16,
                       block_size=4, num_blocks=3)       # 2 usable blocks
        bad = [E.EngineRequest(rid=0, prompt=(1, 2, 3, 4, 5, 6),
                               max_new_tokens=6)]        # needs 3 blocks
        with pytest.raises(E.RequestTooLong, match="KV blocks"):
            eng.serve(bad)

    def test_engine_config_validation(self, dense_setup):
        cfg, params = dense_setup
        with pytest.raises(ValueError, match="power of two"):
            E.Engine(cfg, params, block_size=3)
        with pytest.raises(ValueError, match="block_size"):
            E.Engine(cfg, params, num_blocks=8)
        scfg = get_config("mamba2-1.3b").reduced()
        with pytest.raises(ValueError, match="paged"):
            E.Engine(scfg, R.init(KEY, scfg), block_size=4)


# ---------------------------------------------------------------------------
# memory-aware admission policy
# ---------------------------------------------------------------------------

class TestMemoryAwareAdmission:
    def _policy(self):
        return bt.AdmissionPolicy(lambda b: 0.0, max_batch=8,
                                  max_wait_s=0.0)

    def test_costs_shrink_batch_to_budget(self):
        act = self._policy().decide(0.0, [float("inf")] * 4,
                                    costs=[3, 3, 3, 3], budget=7)
        assert act.launch and act.batch == 2      # 3 + 3 <= 7 < 9

    def test_unaffordable_head_waits(self):
        act = self._policy().decide(0.0, [float("inf")] * 2,
                                    next_arrival=1.5,
                                    costs=[10, 1], budget=4)
        assert not act.launch and act.wait_until == 1.5

    def test_no_costs_is_unchanged(self):
        a = self._policy().decide(0.0, [float("inf")] * 4)
        b = self._policy().decide(0.0, [float("inf")] * 4,
                                  costs=None, budget=None)
        assert (a.launch, a.batch) == (b.launch, b.batch) == (True, 4)


# ---------------------------------------------------------------------------
# shared-prefix trace synthesis
# ---------------------------------------------------------------------------

class TestSharedPrefixTraces:
    def test_prefix_identical_across_requests(self):
        reqs = E.synthetic_requests(8, rate_per_s=100.0, vocab=64,
                                    prompt_len=6, shared_prefix_len=4)
        heads = {r.prompt[:4] for r in reqs}
        tails = {r.prompt[4:] for r in reqs}
        assert len(heads) == 1 and len(tails) == 8

    def test_default_reproduces_old_prompts(self):
        a = E.synthetic_requests(4, rate_per_s=100.0, vocab=64,
                                 prompt_len=5)
        b = E.synthetic_requests(4, rate_per_s=100.0, vocab=64,
                                 prompt_len=5, shared_prefix_len=0)
        assert [r.prompt for r in a] == [r.prompt for r in b]
        assert a[0].prompt == tuple(1 + (0 * 7 + 3 * j) % 63
                                    for j in range(5))

    def test_validation(self):
        with pytest.raises(ValueError, match="shared_prefix_len"):
            E.synthetic_requests(2, rate_per_s=1.0, vocab=8,
                                 prompt_len=4, shared_prefix_len=5)


# ---------------------------------------------------------------------------
# poison: stale bytes in recycled blocks are never read
# ---------------------------------------------------------------------------

class TestPoisonedBlocks:
    def test_new_tenant_never_reads_stale_block_bytes(self, dense_setup):
        """Fill EVERY physical block (trash included) with finite garbage
        — a previous tenant's worst-case leftovers — then serve one
        request through freshly 'allocated' blocks with the raw paged
        steps.  Greedy outputs must equal the sequential reference: every
        read past the row's frontier (and every trash-block byte) is
        masked, so the garbage is unreachable."""
        cfg, params = dense_setup
        prompt, gen = (3, 1, 4, 1, 5), 4
        req = E.EngineRequest(rid=0, prompt=prompt, max_new_tokens=gen)
        want = E.reference_outputs(cfg, params, [req], max_seq=16)[0]

        cache = dict(R.init_paged_cache(cfg, 2, 16, 4, 9))
        for k in cache:
            if k == "block_tables":
                continue
            poison = 77 if cache[k].dtype == jnp.int8 else 3.5
            cache[k] = jnp.full_like(cache[k], poison)
        tables = np.zeros((2, 4), np.int32)
        tables[0] = [1, 2, 3, 4]          # slot 0's "new" blocks
        cache["block_tables"] = jnp.asarray(tables)

        chunk = ST.jit_prefill_chunk_step(
            ST.make_prefill_chunk_step(cfg, mode=FP, chunk=4))
        step = ST.jit_slot_decode_step(ST.make_slot_decode_step(cfg))
        cache = chunk(params, jnp.asarray(prompt[:4], jnp.int32), cache,
                      jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
                      jnp.asarray(4, jnp.int32))
        tokens = np.zeros((2, 1), np.int32)
        tokens[0, 0] = prompt[4]
        index = jnp.asarray([4, 0], jnp.int32)
        active = jnp.asarray([True, False])
        got = []
        for _ in range(gen):
            nxt, cache, index = step(params, jnp.asarray(tokens), cache,
                                     index, active)
            tok = int(np.asarray(nxt)[0])
            got.append(tok)
            tokens[0, 0] = tok
        assert got == want


# ---------------------------------------------------------------------------
# engine-level paged parity
# ---------------------------------------------------------------------------

class TestPagedEngineParity:
    def test_shared_prefix_parity_with_live_sharers(self, dense_setup):
        """Paged engine vs sequential reference, bit-for-bit, on a trace
        where later requests share the earlier ones' prefix blocks WHILE
        those are still decoding — parity proves registered blocks are
        immutable under sharing (copy-on-extend, no mutation)."""
        cfg, params = dense_setup
        reqs = E.synthetic_requests(24, rate_per_s=2000.0, vocab=cfg.vocab,
                                    prompt_len=6, max_new_tokens=5,
                                    shared_prefix_len=4)
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        eng = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       prefill_chunk=4, block_size=4)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
        assert rep.outputs() == want
        assert rep.shared_block_hits > 0
        assert rep.prefill_tokens_skipped == \
            rep.shared_block_hits * eng.block_size
        assert rep.block_size == 4 and rep.kv_hbm_bytes > 0
        assert 0.0 < rep.mean_block_util <= 1.0
        assert 0.0 < rep.shared_hit_rate < 1.0

    def test_blocks_limited_admission_completes(self, dense_setup):
        """More slots than the block budget can fill contiguously: the
        memory-aware policy holds requests until blocks drain, never
        overruns the pool, and still finishes the trace bit-for-bit."""
        cfg, params = dense_setup
        reqs = E.synthetic_requests(12, rate_per_s=5000.0, vocab=cfg.vocab,
                                    prompt_len=6, max_new_tokens=5)
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        rep = E.Engine(cfg, params, num_slots=8, max_seq=16,
                       prefill_chunk=4, block_size=4,
                       num_blocks=17).serve(reqs, clock="virtual",
                                            tick_s=1e-3)
        assert rep.outputs() == want and len(rep.results) == 12
        assert rep.peak_blocks_used <= 16
        assert max(rep.occupancy) > 4     # beyond 4 contiguous rows' worth

    def test_moe_paged_parity(self):
        cfg = get_config("qwen2-moe-a2.7b").reduced()
        params = R.init(KEY, cfg)
        reqs = E.synthetic_requests(6, rate_per_s=2000.0, vocab=cfg.vocab,
                                    prompt_len=6, max_new_tokens=4)
        want = E.reference_outputs(cfg, params, reqs, max_seq=16)
        rep = E.Engine(cfg, params, num_slots=4, max_seq=16,
                       block_size=4).serve(reqs, clock="virtual",
                                           tick_s=1e-3)
        assert rep.outputs() == want

    def test_prime_family_shares_only_on_matching_source(self):
        """encdec prefixes are fingerprinted by the request SOURCE as
        well as the tokens: identical prompts with different sources must
        not share blocks (their self-KV differs through cross-attention),
        while identical sources do share — parity holds either way."""
        cfg = get_config("whisper-medium").reduced()
        params = R.init(KEY, cfg)
        shape = R.source_shape(cfg)
        reqs = E.synthetic_requests(6, rate_per_s=2000.0, vocab=cfg.vocab,
                                    prompt_len=6, max_new_tokens=4,
                                    shared_prefix_len=6,
                                    source_shape=shape)
        eng = E.Engine(cfg, params, num_slots=2, max_seq=16,
                       prefill_chunk=4, block_size=4)
        rep = eng.serve(reqs, clock="virtual", tick_s=1e-3)
        assert rep.outputs() == E.reference_outputs(cfg, params, reqs,
                                                    max_seq=16)
        assert rep.shared_block_hits == 0     # sources differ per rid
        same = [dataclasses.replace(r, source=np.asarray(reqs[0].source))
                for r in reqs]
        rep2 = eng.serve(same, clock="virtual", tick_s=1e-3)
        assert rep2.outputs() == E.reference_outputs(cfg, params, same,
                                                     max_seq=16)
        assert rep2.shared_block_hits > 0
