"""Latency-aware batching: Table 4 reproduction + scheduler properties."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import batching as bt


class TestTable4:
    def test_tpu_batch200_at_7ms(self):
        b, lat, ips, frac = bt.table4_row(bt.TABLE4_TPU, 7e-3, max_batch=250)
        assert b == 200                       # paper: batch 200
        assert lat == pytest.approx(7e-3, rel=0.01)
        assert ips == pytest.approx(225000, rel=0.01)
        assert frac == pytest.approx(0.80, abs=0.02)   # "80%"

    def test_cpu_gpu_forced_to_small_batches(self):
        bc, _, _, fc = bt.table4_row(bt.TABLE4_CPU, 7e-3, max_batch=64)
        bg, _, _, fg = bt.table4_row(bt.TABLE4_GPU, 7e-3, max_batch=64)
        assert bc <= 16 and bg <= 32          # paper: both use 16
        assert fc < 0.5 and fg < 0.6          # 42% / 37% of max IPS

    def test_ordering_tpu_best(self):
        fr = {m.name: bt.table4_row(m, 7e-3, max_batch=250)[3]
              for m in (bt.TABLE4_CPU, bt.TABLE4_GPU, bt.TABLE4_TPU)}
        assert fr["TPU"] > fr["Haswell"] and fr["TPU"] > fr["K80"]


class TestChooseBatch:
    @given(st.floats(1e-3, 50e-3), st.integers(1, 512))
    @settings(max_examples=30, deadline=None)
    def test_never_exceeds_deadline(self, deadline, max_batch):
        b = bt.choose_batch(bt.TABLE4_TPU, deadline, max_batch)
        if b:
            assert bt.TABLE4_TPU.p99_latency(b) <= deadline + 1e-12
            assert b <= max_batch

    @given(st.floats(1e-3, 50e-3))
    @settings(max_examples=30, deadline=None)
    def test_maximal(self, deadline):
        b = bt.choose_batch(bt.TABLE4_TPU, deadline, 4096)
        if 0 < b < 4096:
            assert bt.TABLE4_TPU.p99_latency(b + 1) > deadline


class TestBatchQueue:
    def _run(self, rate, n=500, deadline=7e-3, max_batch=200, seed=0):
        reqs = bt.poisson_arrivals(rate, n, deadline, seed)
        q = bt.BatchQueue(bt.TABLE4_TPU.service_time, max_batch=max_batch)
        return reqs, q.run(reqs)

    def test_all_requests_served_once(self):
        reqs, recs = self._run(rate=20000)
        served = [r for rec in recs for r in rec.rids]
        assert sorted(served) == list(range(len(reqs)))

    def test_deadlines_met_at_moderate_load(self):
        _, recs = self._run(rate=20000)
        met = sum(r.deadlines_met for r in recs) / len(recs)
        assert met > 0.95

    def test_batches_grow_with_load(self):
        _, light = self._run(rate=2000)
        _, heavy = self._run(rate=50000)
        mean = lambda rs: sum(len(r.rids) for r in rs) / len(rs)
        assert mean(heavy) > mean(light) * 2

    @given(st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_no_batch_exceeds_max(self, seed):
        _, recs = self._run(rate=30000, n=300, seed=seed)
        assert all(len(r.rids) <= 200 for r in recs)

    def test_virtual_time_monotone(self):
        _, recs = self._run(rate=20000)
        for a, b in zip(recs, recs[1:]):
            assert b.start_s >= a.finish_s - 1e-12


class TestP99:
    """Nearest-rank p99: ceil(0.99 n)-th order statistic, exactly."""

    @pytest.mark.parametrize("n,want_rank", [
        (1, 1),      # a single sample IS its own p99
        (99, 99),    # ceil(98.01) = 99 -> the max, correctly
        (100, 99),   # the regression: int(0.99*100)=100th (max) was wrong
        (101, 100),  # ceil(99.99) = 100 -> second-largest
    ])
    def test_boundary_ranks(self, n, want_rank):
        xs = [float(i) for i in range(n)]
        assert bt.p99(xs) == xs[want_rank - 1]

    def test_n100_is_not_the_max(self):
        """The off-by-one this fixes: at n=100 the old int(0.99*n)
        indexing returned the maximum, overstating tail latency by a
        whole rank."""
        xs = [1.0] * 99 + [1000.0]
        assert bt.p99(xs) == 1.0

    def test_input_order_irrelevant_and_empty(self):
        import random
        xs = [float(i) for i in range(101)]
        random.Random(3).shuffle(xs)
        assert bt.p99(xs) == 99.0
        assert bt.p99([]) == 0.0


def test_perfmodel_integration():
    """batching consumes core.perfmodel service times end-to-end."""
    from repro.core import perfmodel as pm
    app = pm.APP_BY_NAME["MLP0"]
    service = lambda b: pm.service_time(app, batch=b)
    q = bt.BatchQueue(service, max_batch=200)
    reqs = bt.poisson_arrivals(50000, 400, deadline_s=7e-3)
    recs = q.run(reqs)
    assert recs and all(len(r.rids) <= 200 for r in recs)
