"""Pallas kernel validation: interpret-mode sweeps vs the jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.quant import quantize, quantize_weight
from repro.kernels import ops, qmatmul as K, ref


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# exact block-multiple shapes exercise the kernel without the padding path;
# ragged shapes exercise ops.py padding.
SHAPES = [
    (128, 256, 128),
    (256, 512, 256),
    (128, 256, 384),
    (70, 300, 200),      # ragged
    (1, 256, 128),       # single row (decode-like)
    (257, 513, 129),     # all ragged
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("activation", ["none", "relu", "gelu"])
def test_w8a16_matches_ref(m, k, n, activation):
    keys = jax.random.split(jax.random.PRNGKey(m * 7 + k + n), 3)
    x = _rand(keys[0], (m, k))
    w = quantize_weight(_rand(keys[1], (k, n)))
    b = _rand(keys[2], (n,))
    got = ops.qmatmul(x, w, b, activation=activation, interpret=True,
                      out_dtype=jnp.float32)
    want = ref.qmatmul_w8a16_ref(x, w.values, w.scale.reshape(-1), b,
                                 activation=activation,
                                 out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", SHAPES[:4])
@pytest.mark.parametrize("activation", ["none", "sigmoid", "tanh"])
def test_w8a8_matches_ref(m, k, n, activation):
    keys = jax.random.split(jax.random.PRNGKey(m + k * 3 + n), 3)
    x = _rand(keys[0], (m, k))
    xq = quantize(x, bits=8, axis=None)
    w = quantize_weight(_rand(keys[1], (k, n)))
    b = _rand(keys[2], (n,))
    got = ops.qmatmul(x, w, b, x_q=xq, activation=activation,
                      interpret=True, out_dtype=jnp.float32)
    want = ref.qmatmul_w8a8_ref(xq.values, w.values, xq.scale,
                                w.scale.reshape(-1), b,
                                activation=activation,
                                out_dtype=jnp.float32)
    # integer path: accumulation is exact; only the final fp ops differ
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_w8a8_integer_accumulate_exact():
    """With unit scales the kernel must be bit-exact integer arithmetic."""
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (128, 256), -127, 127, jnp.int8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (256, 128),
                           -127, 127, jnp.int8)
    one = jnp.ones((), jnp.float32)
    got = K.qmatmul_w8a8(x, w, one, jnp.ones((128,)), None,
                         interpret=True, out_dtype=jnp.float32)
    want = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_w8a16_out_dtypes(dtype):
    keys = jax.random.split(jax.random.PRNGKey(5), 2)
    x = _rand(keys[0], (128, 256), dtype)
    w = quantize_weight(_rand(keys[1], (256, 128)))
    got = ops.qmatmul(x, w, None, interpret=True, out_dtype=dtype)
    assert got.dtype == dtype
    ref_out = ref.qmatmul_w8a16_ref(x, w.values, w.scale.reshape(-1), None,
                                    out_dtype=dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_out, np.float32),
        rtol=2e-2, atol=2e-2)


def test_nd_input_flattening():
    keys = jax.random.split(jax.random.PRNGKey(6), 2)
    x = _rand(keys[0], (2, 3, 5, 96))
    w = quantize_weight(_rand(keys[1], (96, 64)))
    got = ops.qmatmul(x, w, None, interpret=True, out_dtype=jnp.float32)
    assert got.shape == (2, 3, 5, 64)
    flat = ops.qmatmul(x.reshape(-1, 96), w, None, interpret=True,
                       out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got).reshape(-1, 64),
                               np.asarray(flat), rtol=1e-6)


@given(st.integers(0, 2**31 - 1),
       st.sampled_from([(64, 128, 64), (32, 256, 96)]),
       st.floats(0.1, 4.0))
@settings(max_examples=10, deadline=None)
def test_quantized_matmul_error_vs_fp_bounded(seed, shape, scale):
    """Property: w8a16 output error vs the fp matmul is bounded by the
    quantization step of the weights (relative error ~ 1/127)."""
    m, k, n = shape
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = _rand(keys[0], (m, k), scale=scale)
    w_fp = _rand(keys[1], (k, n), scale=scale)
    w = quantize_weight(w_fp)
    got = ops.qmatmul(x, w, None, interpret=True, out_dtype=jnp.float32)
    want = x @ w_fp
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02


def test_cpu_fallback_matches_interpret():
    """ops.py CPU fallback (oracle) and interpret-mode kernel agree."""
    keys = jax.random.split(jax.random.PRNGKey(7), 2)
    x = _rand(keys[0], (64, 128))
    w = quantize_weight(_rand(keys[1], (128, 64)))
    a = ops.qmatmul(x, w, None, interpret=True, out_dtype=jnp.float32)
    b = ops.qmatmul(x, w, None, interpret=False, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------

FLASH_SHAPES = [
    (2, 256, 2, 128, True, None),
    (1, 128, 4, 64, True, None),       # hd padding path
    (2, 200, 2, 128, True, 64),        # ragged seq + sliding window
    (1, 384, 1, 128, False, None),     # non-causal (cross-attention)
]


@pytest.mark.parametrize("b,s,h,hd,causal,win", FLASH_SHAPES)
def test_flash_attention_matches_ref(b, s, h, hd, causal, win):
    keys = jax.random.split(jax.random.PRNGKey(s + hd), 3)
    q = _rand(keys[0], (b, s, h, hd))
    k = _rand(keys[1], (b, s, h, hd))
    v = _rand(keys[2], (b, s, h, hd))
    got = ops.flash_attention(q, k, v, causal=causal, window=win,
                              interpret=True, out_dtype=jnp.float32)
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    want = ref.flash_attention_ref(
        qr, kr, vr, causal=causal, window=win, out_dtype=jnp.float32
    ).reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_chunked_attention():
    """The Pallas kernel and the pure-JAX chunked attention (the model's
    CPU/dry-run path) agree — they are interchangeable implementations."""
    from repro.models.layers import _chunked_attention
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    b, s, h, hd = 2, 160, 2, 64
    q = _rand(keys[0], (b, s, h, hd))
    k = _rand(keys[1], (b, s, h, hd))
    v = _rand(keys[2], (b, s, h, hd))
    a = ops.flash_attention(q, k, v, causal=True, interpret=True,
                            out_dtype=jnp.float32)
    c = _chunked_attention(q, k, v, causal=True, window=None, q_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c, np.float32),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_flash_attention_rows_sum_property(seed):
    """With v = ones, every output row must be exactly 1 (softmax rows
    sum to 1) regardless of masking pattern — catches denominator bugs."""
    key = jax.random.PRNGKey(seed)
    b, s, h, hd = 1, 128, 2, 128
    q = _rand(key, (b, s, h, hd))
    k = _rand(jax.random.fold_in(key, 1), (b, s, h, hd))
    v = jnp.ones((b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True,
                              out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)
