"""Replica router: the fleet front-end over N engine replicas.

The load-bearing property: the router NEVER routes an admission a
replica's own AdmissionPolicy would reject — every RouteDecision the
router records replays through ``policy.decide`` on exactly the
projected state the router consulted, and launches.  Plus: routed
serving is bit-for-bit the single-engine reference, refusals are typed
(never silent), plans are deterministic, and the report rolls up
per-replica accounting."""
import dataclasses

import jax
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine as E
from repro.configs import get_config
from repro.core import batching as bt
from repro.models import registry as R

KEY = jax.random.PRNGKey(0)


def _cfg():
    return dataclasses.replace(get_config("starcoder2-3b").reduced(),
                               kv_quant=True)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = _cfg()
    return cfg, R.init(KEY, cfg)


def _replicas(cfg, params, n=2, *, policy=None, num_slots=4, **kw):
    return [E.Engine(cfg, params, num_slots=num_slots, max_seq=16,
                     prefill_chunk=2,
                     policy=policy() if policy else None, **kw)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def test_router_validates_construction(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="at least one"):
        E.ReplicaRouter([])
    engines = _replicas(cfg, params, 2)
    with pytest.raises(ValueError, match="names"):
        E.ReplicaRouter(_replicas(cfg, params, 2), names=["only-one"])
    with pytest.raises(ValueError, match="unique"):
        E.ReplicaRouter(_replicas(cfg, params, 2), names=["r", "r"])
    rt = E.ReplicaRouter(engines)
    assert rt.names == ["replica0", "replica1"]
    assert [e.name for e in engines] == rt.names   # names stick


def test_router_rejects_mismatched_lane_sets(dense_setup):
    cfg, params = dense_setup
    single = E.Engine(cfg, params, num_slots=4, max_seq=16)
    multi = E.Engine(models={"a": (cfg, params)}, num_slots=4, max_seq=16)
    with pytest.raises(ValueError, match="same model lanes"):
        E.ReplicaRouter([single, multi])


# ---------------------------------------------------------------------------
# the admission property
# ---------------------------------------------------------------------------

class TestRouterAdmissionProperty:
    @given(st.integers(0, 30), st.sampled_from([500.0, 5000.0, 50000.0]),
           st.sampled_from([None, 1, 2]))
    @settings(max_examples=10, deadline=None)
    def test_router_never_overrides_replica_policy(self, seed, rate,
                                                   batch_quota):
        """Replay every RouteDecision through the target replica's own
        AdmissionPolicy on exactly the projected state the router
        consulted: the policy must launch.  Quotas, capacity, and SLO
        classes all flow through ``decide`` — the router is a
        placement layer, never an admission override."""
        cfg = _cfg()
        quotas = ({"batch": batch_quota} if batch_quota is not None
                  else None)
        policies = [bt.AdmissionPolicy(lambda b: 1e-4, max_batch=4,
                                       max_wait_s=0.0,
                                       class_quotas=quotas)
                    for _ in range(2)]
        # route() needs engine shape + policy, not device state: a
        # light stand-in keeps 30 hypothesis examples cheap
        reps = [_FakeEngine(num_slots=4, policy=p) for p in policies]
        rt = E.ReplicaRouter.__new__(E.ReplicaRouter)
        rt.engines, rt.names = reps, ["r0", "r1"]
        reqs = E.synthetic_requests(
            40, rate_per_s=rate, vocab=256, prompt_len=3,
            max_new_tokens=4, seed=seed,
            priority=lambda rid: "batch" if rid % 2 else "interactive")
        plan = rt.route(reqs)
        by_rid = {r.rid: r for r in reqs}
        assert plan.decisions            # something was actually routed
        for dec in plan.decisions:
            r = by_rid[dec.rid]
            eng = reps[rt.names.index(dec.replica)]
            key = r.priority             # single-model engines
            act = eng.policy.decide(
                dec.now, [r.deadline_s], next_arrival=None,
                capacity=dec.capacity, classes=[key],
                active_by_class=dict(dec.active_by_class))
            assert act.launch and act.batch >= 1, (
                f"router admitted rid {dec.rid} on {dec.replica} where "
                f"its policy refuses: {dec}")
        # conservation: every request is assigned exactly once or refused
        routed = [r.rid for sub in plan.assignments.values() for r in sub]
        assert sorted(routed + [r.rid for r in plan.refused]) == \
            sorted(by_rid)


class _FakeEngine:
    """Just enough Engine surface for ReplicaRouter.route: the
    projection consults num_slots, multi, lanes, and policy only."""
    multi = False
    lanes = {None: None}
    name = None

    def __init__(self, *, num_slots, policy):
        self.num_slots = num_slots
        self.policy = policy


def test_hard_capped_quota_refuses_typed(dense_setup):
    """A class whose quota is zero on EVERY replica is permanently
    unroutable: route() returns it in ``refused`` (bounded — no
    spinning on the projection clock) and serve() synthesizes a typed
    ``refused`` result, never a silent drop."""
    cfg, params = dense_setup
    mk = lambda: bt.AdmissionPolicy(lambda b: 0.0, max_batch=4,
                                    max_wait_s=0.0,
                                    class_quotas={"batch": 0})
    rt = E.ReplicaRouter(_replicas(cfg, params, 2, policy=mk))
    reqs = E.synthetic_requests(
        8, rate_per_s=2000.0, vocab=cfg.vocab, prompt_len=3,
        max_new_tokens=4,
        priority=lambda rid: "batch" if rid % 2 else "interactive")
    plan = rt.route(reqs)
    assert {r.rid for r in plan.refused} == \
        {r.rid for r in reqs if r.priority == "batch"}
    rep = rt.serve(reqs, tick_s=1e-3)
    assert len(rep.results) == len(reqs)          # nothing lost
    statuses = {r.rid: r.status for r in rep.results}
    for r in reqs:
        want = "refused" if r.priority == "batch" else "ok"
        assert statuses[r.rid] == want
    assert rep.refused == len(plan.refused)
    ref = [r for r in rep.results if r.status == "refused"]
    assert all(r.tokens == [] and r.slot == -1 for r in ref)


# ---------------------------------------------------------------------------
# routed serving
# ---------------------------------------------------------------------------

def test_routed_outputs_match_reference_and_balance(dense_setup):
    """2 replicas, one hot trace: routed outputs are bit-for-bit the
    sequential reference (replicas share no state, so placement cannot
    change bits), both replicas take work, and the rollup report's
    accounting is consistent."""
    cfg, params = dense_setup
    rt = E.ReplicaRouter(_replicas(cfg, params, 2))
    reqs = E.synthetic_requests(40, rate_per_s=20000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=5)
    rep = rt.serve(reqs, tick_s=1e-3)
    want = E.reference_outputs(cfg, params, reqs, max_seq=16)
    assert {r.rid: r.tokens for r in rep.results
            if r.status == "ok"} == want
    assert rep.refused == 0
    assert min(rep.replica_requests.values()) > 0   # nobody starved
    assert sum(rep.replica_requests.values()) == len(reqs)
    assert set(rep.replicas) == {"replica0", "replica1"}
    assert rep.generated_tokens == sum(
        r.generated_tokens for r in rep.replicas.values())
    assert rep.duration_s == max(
        r.duration_s for r in rep.replicas.values())
    assert rep.leaked_blocks == 0
    assert rep.outputs() == {r.rid: r.tokens for r in rep.results}


def test_route_plan_is_deterministic(dense_setup):
    cfg, params = dense_setup
    reqs = E.synthetic_requests(30, rate_per_s=20000.0, vocab=cfg.vocab,
                                prompt_len=4, max_new_tokens=5)
    plans = [E.ReplicaRouter(_replicas(cfg, params, 3)).route(reqs)
             for _ in range(2)]
    a, b = plans
    assert {n: [r.rid for r in sub] for n, sub in a.assignments.items()} \
        == {n: [r.rid for r in sub] for n, sub in b.assignments.items()}
    assert [d.rid for d in a.decisions] == [d.rid for d in b.decisions]
    assert [d.replica for d in a.decisions] == \
        [d.replica for d in b.decisions]
