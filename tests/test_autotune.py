"""Autotuner validation: legality invariants, VMEM-budget discipline for
every registry arch (reduced mode), cache persistence, and interpret-mode
parity of tuned small-M tiles vs the kernels/ref.py oracles."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config, list_archs
from repro.core.quant import quantize, quantize_weight
from repro.kernels import autotune as AT
from repro.kernels import ops, ref


PROBLEMS = [
    # (m, k, n) spanning decode (small M) to prefill/train (large M)
    (8, 256, 128), (16, 4096, 4096), (32, 512, 1024),
    (64, 1024, 256), (128, 256, 128), (200, 300, 500),
    (1, 128, 128), (2048, 4096, 8192),
]


# ---------------------------------------------------------------------------
# legality invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", AT.MODES)
@pytest.mark.parametrize("m,k,n", PROBLEMS)
def test_candidates_are_legal(m, k, n, mode):
    """Every enumerated candidate is lane/sublane aligned and fits the
    double-buffered working set in the VMEM budget."""
    xd = AT.x_dtype_for(mode)
    cands = AT.enumerate_candidates(m, k, n, mode=mode)
    assert cands, f"no candidates for {(m, k, n)} {mode}"
    for c in cands:
        assert c.bm % AT.SUBLANE[xd] == 0, (c, xd)
        assert c.bn % AT.LANE == 0 and c.bk % AT.LANE == 0, c
        if mode == "w8a8":
            assert c.bk % 256 == 0, c
        assert AT.vmem_bytes(c, mode=mode) <= AT.DEFAULT_VMEM_BUDGET, c
        # padded problem divides exactly into blocks (the kernels assert
        # divisibility; ops.py pads to these multiples)
        for size, blk in ((m, c.bm), (n, c.bn), (k, c.bk)):
            assert (-(-size // blk) * blk) % blk == 0


@pytest.mark.parametrize("mode,x_dtype,m,want_bm", [
    ("w8a16", "f32", 8, 8),      # f32 acts: 8-sublane floor -> true GEMV tile
    ("w8a16", "bf16", 8, 16),    # bf16 acts: 16-sublane floor
    ("w8a8", "bf16", 32, 32),    # int8 acts: 32-sublane floor
    ("w8a16", "f32", 32, 32),
])
def test_ranked_best_respects_budget_and_beats_padding(mode, x_dtype, m,
                                                       want_bm):
    """The winner never exceeds the budget, and for decode-sized M it
    picks the smallest legal row tile instead of padding to 128 rows (the
    whole point of the small-M path)."""
    ranked = AT.rank_candidates(m, 4096, 4096, mode=mode, x_dtype=x_dtype)
    best = ranked[0]
    assert AT.vmem_bytes(best, mode=mode, x_dtype=x_dtype) \
        <= AT.DEFAULT_VMEM_BUDGET
    assert best.bm == want_bm, \
        f"decode M={m} should pick a {want_bm}-row tile, got {best}"


def test_out_dtype_tightens_bm_floor():
    """The (bm, bn) output tile is a real block: a bf16 output forbids
    8-row tiles even when the streamed x is f32."""
    best_f32 = AT.rank_candidates(8, 4096, 4096, mode="w8a16",
                                  x_dtype="f32", out_dtype="f32")[0]
    best_bf16 = AT.rank_candidates(8, 4096, 4096, mode="w8a16",
                                   x_dtype="f32", out_dtype="bf16")[0]
    assert best_f32.bm == 8
    assert best_bf16.bm == 16
    assert not AT.is_legal(AT.TileConfig(8, 128, 128), mode="w8a16",
                           x_dtype="f32", out_dtype="bf16")
    # distinct cache keys: a winner tuned for f32 output is never reused
    # for bf16 output
    assert AT.AutotuneCache.key(8, 4096, 4096, "w8a16", "f32", "f32",
                                True, "tpu") != \
        AT.AutotuneCache.key(8, 4096, 4096, "w8a16", "f32", "bf16",
                             True, "tpu")


def test_budget_excludes_oversized_configs():
    huge = AT.TileConfig(2048, 1024, 1024)
    assert AT.vmem_bytes(huge, mode="w8a16", x_dtype="f32") \
        > AT.DEFAULT_VMEM_BUDGET
    assert not AT.is_legal(huge, mode="w8a16", x_dtype="f32")
    # every enumerated shape stays inside VMEM even before the budget cap:
    # the candidate pools are sized so the working set can never approach
    # the physical 16 MiB, but the budget check is still the hard gate
    worst = AT.TileConfig(max(AT.BM_CANDIDATES), max(AT.BN_CANDIDATES),
                          max(AT.BK_CANDIDATES))
    assert AT.vmem_bytes(worst, mode="w8a16", x_dtype="f32") < AT.VMEM_BYTES


def test_registry_archs_within_vmem_budget(tmp_path):
    """For every registry arch (reduced mode) and every serving matmul at
    decode/prefill row counts, the autotuner never selects a config
    exceeding the VMEM budget — the ISSUE's acceptance criterion."""
    cache = AT.AutotuneCache(str(tmp_path / "autotune.json"))
    for name in list_archs():
        cfg = get_config(name).reduced()
        for row in AT.tune_arch(cfg, m_values=(8, 32), cache=cache):
            assert row["vmem_bytes"] <= AT.DEFAULT_VMEM_BUDGET, row
            tc = AT.TileConfig(row["bm"], row["bn"], row["bk"])
            assert AT.is_legal(tc, mode=row["mode"]), row


# ---------------------------------------------------------------------------
# cost model sanity
# ---------------------------------------------------------------------------

def test_cost_model_penalizes_padding():
    """A 128-row tile on an 8-row problem costs strictly more than an
    8-row tile (16x the padded flops and x-bytes)."""
    c_small = AT.TileConfig(8, 256, 512)
    c_big = AT.TileConfig(128, 256, 512)
    assert AT.predicted_cost(8, 4096, 4096, c_small, x_dtype="f32") \
        < AT.predicted_cost(8, 4096, 4096, c_big, x_dtype="f32")


def test_cost_model_prefers_weight_reuse_at_large_m():
    """At train-sized M, tiny row tiles re-stream the weights M/bm times;
    the model must prefer larger bm."""
    best = AT.rank_candidates(2048, 4096, 4096, mode="w8a16")[0]
    assert best.bm >= 64, best


# ---------------------------------------------------------------------------
# JSON cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip_and_schema(tmp_path):
    path = tmp_path / "autotune.json"
    cache = AT.AutotuneCache(str(path))
    tc = AT.best_config(8, 256, 128, mode="w8a16", x_dtype="f32",
                        backend="cpu", cache=cache)
    data = json.loads(path.read_text())
    assert data["schema_version"] == AT.SCHEMA_VERSION
    key = AT.AutotuneCache.key(8, 256, 128, "w8a16", "f32", "f32", True,
                               "cpu")
    assert data["entries"][key]["bm"] == tc.bm
    # a fresh cache object reads the persisted winner back
    again = AT.AutotuneCache(str(path)).get(key)
    assert again == tc


def test_cache_recovers_from_injected_partial_write(tmp_path):
    """A torn cache file (a writer that died mid-file, or a
    pre-atomic-discipline interleaving) is discarded on load — never
    fatal — and the next atomic put leaves a valid file again."""
    import os
    path = tmp_path / "autotune.json"
    tc = AT.TileConfig(16, 128, 128)
    AT.AutotuneCache(str(path)).put("k1", tc)
    text = path.read_text()
    path.write_text(text[:len(text) // 2])          # inject the tear
    torn = AT.AutotuneCache(str(path))
    assert torn.get("k1") is None                   # discarded, no crash
    torn.put("k2", tc)
    reread = json.loads(path.read_text())           # valid JSON again
    assert reread["schema_version"] == AT.SCHEMA_VERSION
    assert AT.AutotuneCache(str(path)).get("k2") == tc
    # the tmp staging file was replaced, not left behind (the .lock
    # sidecar for cross-process exclusion is expected)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_cache_concurrent_writers_merge_not_clobber(tmp_path):
    """Two processes tuning different shapes against one cache file:
    the second writer's read-merge-replace must keep the first's entry
    (a plain rewrite of its own stale snapshot would drop it)."""
    path = str(tmp_path / "autotune.json")
    a, b = AT.AutotuneCache(path), AT.AutotuneCache(path)
    assert b.get("anything") is None       # b snapshots the (empty) file
    a.put("ka", AT.TileConfig(16, 128, 128))
    b.put("kb", AT.TileConfig(32, 256, 256))
    fresh = AT.AutotuneCache(path)
    assert fresh.get("ka") == AT.TileConfig(16, 128, 128)
    assert fresh.get("kb") == AT.TileConfig(32, 256, 256)


def test_cache_hit_skips_ranking(tmp_path, monkeypatch):
    cache = AT.AutotuneCache(str(tmp_path / "autotune.json"))
    first = AT.best_config(16, 512, 512, backend="cpu", cache=cache)
    monkeypatch.setattr(AT, "rank_candidates",
                        lambda *a, **k: pytest.fail("cache miss"))
    second = AT.best_config(16, 512, 512, backend="cpu", cache=cache)
    assert first == second


def test_measured_refinement_uses_timing_backend(tmp_path):
    """A timing backend re-ranks the analytic top candidates: make the
    analytically-worst of the top group the measured winner."""
    cache = AT.AutotuneCache(str(tmp_path / "autotune.json"))
    ranked = AT.rank_candidates(64, 1024, 1024, mode="w8a16")
    want = ranked[min(2, len(ranked) - 1)]
    times = {c: (0.0 if c == want else 1.0) for c in ranked}
    got = AT.best_config(64, 1024, 1024, mode="w8a16", backend="cpu",
                         cache=cache, measure=lambda c: times[c],
                         top_k_measure=3)
    assert got == want


# ---------------------------------------------------------------------------
# interpret-mode parity: tuned tiles vs the jnp oracles
# ---------------------------------------------------------------------------

def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@given(st.sampled_from([8, 16, 32]),
       st.sampled_from([(256, 128), (384, 256), (512, 512)]))
@settings(max_examples=9, deadline=None)
def test_w8a16_tuned_small_m_matches_ref(m, kn):
    """Tuned small-M (GEMV-style) tiles through the real kernel body (the
    Pallas interpreter) agree with the oracle."""
    k, n = kn
    tc = AT.best_config(m, k, n, mode="w8a16", x_dtype="f32",
                        backend="interpret", cache=AT.AutotuneCache(""))
    keys = jax.random.split(jax.random.PRNGKey(m * 31 + k + n), 3)
    x = _rand(keys[0], (m, k))
    w = quantize_weight(_rand(keys[1], (k, n)))
    b = _rand(keys[2], (n,))
    got = ops.qmatmul(x, w, b, interpret=True, out_dtype=jnp.float32,
                      **tc.as_kwargs())
    want = ref.qmatmul_w8a16_ref(x, w.values, w.scale.reshape(-1), b,
                                 out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@given(st.sampled_from([8, 16, 32]),
       st.sampled_from([(256, 128), (512, 256)]))
@settings(max_examples=6, deadline=None)
def test_w8a8_tuned_small_m_matches_ref(m, kn):
    k, n = kn
    tc = AT.rank_candidates(m, k, n, mode="w8a8")[0]
    keys = jax.random.split(jax.random.PRNGKey(m + 7 * k + n), 3)
    x = _rand(keys[0], (m, k))
    xq = quantize(x, bits=8, axis=None)
    w = quantize_weight(_rand(keys[1], (k, n)))
    b = _rand(keys[2], (n,))
    got = ops.qmatmul(x, w, b, x_q=xq, interpret=True,
                      out_dtype=jnp.float32, **tc.as_kwargs())
    want = ref.qmatmul_w8a8_ref(xq.values, w.values, xq.scale,
                                w.scale.reshape(-1), b,
                                out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (256, 512, 384)])
def test_w8a16_tuned_aligned_matches_ref(m, k, n):
    """128-aligned shapes through the default (autotuned) dispatch."""
    keys = jax.random.split(jax.random.PRNGKey(m + k + n), 2)
    x = _rand(keys[0], (m, k))
    w = quantize_weight(_rand(keys[1], (k, n)))
    got = ops.qmatmul(x, w, None, interpret=True, out_dtype=jnp.float32)
    want = ref.qmatmul_w8a16_ref(x, w.values, w.scale.reshape(-1), None,
                                 out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bias_free_path_streams_no_bias_tile():
    """The conditional-operand rework: with bias=None the kernel call
    receives no bias operand at all (one fewer VMEM stream per tile)."""
    from repro.kernels import qmatmul as K
    keys = jax.random.split(jax.random.PRNGKey(3), 2)
    x = _rand(keys[0], (64, 256))
    w = quantize_weight(_rand(keys[1], (256, 128)))
    got = K.qmatmul_w8a16(x.astype(jnp.float32), w.values,
                          w.scale.reshape(-1), None, bm=64, bn=128, bk=256,
                          interpret=True, out_dtype=jnp.float32)
    want = ref.qmatmul_w8a16_ref(x, w.values, w.scale.reshape(-1), None,
                                 out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
