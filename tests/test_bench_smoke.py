"""Guard for every future perf PR: `benchmarks/run.py --smoke --bench-out`
exits 0 offline and the BENCH JSON schema is stable."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED_KINDS = {"tokens_per_s", "service_time", "chosen_tile",
                  "kernel_bench", "engine"}
ROW_KEYS = {
    "tokens_per_s": {"arch", "batch", "num_tokens", "tokens_per_s",
                     "seconds"},
    "service_time": {"arch", "batch", "seconds"},
    "chosen_tile": {"arch", "op", "m", "k", "n", "mode", "bm", "bn", "bk",
                    "vmem_bytes"},
    "kernel_bench": {"name", "us_per_call", "derived"},
    "engine": {"arch", "family", "rate", "n_requests", "num_slots",
               "p99_s", "tokens_per_s", "mean_occupancy", "ticks",
               "admissions_while_busy", "occupancy_curve",
               "prefill_chunk", "mean_ttft_s", "p99_ttft_s",
               "block_size", "num_blocks", "kv_hbm_bytes",
               "peak_blocks_used", "mean_block_util", "shared_block_hits",
               "shared_hit_rate", "prefill_tokens_skipped",
               "effective_concurrency",
               # overload robustness: per-SLO-class tails + goodput
               "class_p99_latency_s", "class_mean_ttft_s",
               "class_p99_ttft_s", "goodput_tokens_per_s",
               "slo_attainment", "preempted", "dropped", "failed",
               "unfinished",
               # speculative decoding: draft-and-verify accounting
               "spec_k", "draft_layers", "accepted_per_dispatch",
               "latency_per_token_s",
               # multi-model multiplexing: the row's lane label plus
               # per-model tail/goodput/occupancy columns (empty dicts
               # on single-model rows)
               "model", "model_p99_s", "model_mean_ttft_s",
               "model_goodput_tokens_per_s", "model_mean_occupancy",
               # scale-out: replica/tensor-parallel fleet columns
               # (1/1/{} on ordinary single-engine rows)
               "replicas", "tp", "replica_occupancy"},
}


@pytest.fixture(scope="module")
def bench_doc(tmp_path_factory):
    out = tmp_path_factory.mktemp("bench") / "BENCH_serving.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), REPO,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env.setdefault("REPRO_AUTOTUNE_CACHE",
                   str(tmp_path_factory.mktemp("cache") / "autotune.json"))
    r = subprocess.run(
        [sys.executable, os.path.join("benchmarks", "run.py"), "--smoke",
         "--bench-out", str(out)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"--smoke failed:\n{r.stdout}\n{r.stderr}"
    assert "smoke OK" in r.stdout
    # satellite: kernel_bench rows ride along in the --smoke output
    assert "kernel/qmatmul_" in r.stdout
    # satellite: --smoke runs one short continuous-batching engine trace
    # (sequential-reference parity + append-path kernel parity, offline)
    assert "[engine] smoke:" in r.stdout
    assert "parity OK" in r.stdout
    # satellite: --smoke runs the speculative gate (full-depth self-draft
    # chaos arm + garbage draft + non-spec control, all bit-for-bit)
    assert "[spec] smoke:" in r.stdout
    # satellite: --smoke runs the multi-model gate (two families on one
    # engine under chaos, per-model parity + occupancy consolidation)
    assert "[multiplex] smoke:" in r.stdout
    # satellite: --smoke runs the fleet gate (2 replicas x 2 models
    # behind the router, per-model parity, zero leaked blocks)
    assert "[router] smoke:" in r.stdout
    # satellite: --smoke runs the sharded-executor parity gate (tp=1
    # conformance always; multi-device skips gracefully on 1 device)
    assert "[sharded] smoke:" in r.stdout
    return json.loads(out.read_text())


def test_schema_stable(bench_doc):
    assert bench_doc["schema_version"] == 1
    assert "backend" in bench_doc
    rows = bench_doc["rows"]
    kinds = {row["kind"] for row in rows}
    assert REQUIRED_KINDS <= kinds, kinds
    for row in rows:
        want = ROW_KEYS.get(row["kind"])
        if want:
            assert want <= set(row), (row["kind"], row)


def test_rows_are_sane(bench_doc):
    from repro.kernels import autotune as AT
    for row in bench_doc["rows"]:
        if row["kind"] == "tokens_per_s":
            assert row["tokens_per_s"] > 0
        elif row["kind"] == "service_time":
            assert row["seconds"] > 0
        elif row["kind"] == "chosen_tile":
            # the autotuner never ships a config exceeding the VMEM budget
            assert row["vmem_bytes"] <= AT.DEFAULT_VMEM_BUDGET
            tc = AT.TileConfig(row["bm"], row["bn"], row["bk"])
            assert AT.is_legal(tc, mode=row["mode"]), row
        elif row["kind"] == "engine":
            assert row["p99_s"] > 0 and row["tokens_per_s"] > 0
            assert 0 < row["mean_occupancy"] <= 1
            assert row["admissions_while_busy"] >= 0
            assert all(0 <= a <= row["num_slots"]
                       for a in row["occupancy_curve"])
            assert 0 < row["mean_ttft_s"] <= row["p99_s"]
            assert row["kv_hbm_bytes"] > 0
            assert row["effective_concurrency"] > 0
            if row["block_size"]:             # a paged-engine row
                assert row["num_blocks"] >= 2
                assert 0 < row["peak_blocks_used"] < row["num_blocks"]
                assert 0 < row["mean_block_util"] <= 1
                assert 0 <= row["shared_hit_rate"] < 1
            else:
                assert row["peak_blocks_used"] == 0
                assert row["shared_block_hits"] == 0
            # speculative accounting: apd is exactly 1.0 without a
            # draft (one committed token per dispatch, by construction)
            # and can only improve on it with one
            assert row["latency_per_token_s"] > 0
            if row["spec_k"] == 0:
                assert row["accepted_per_dispatch"] == 1.0
                assert row["draft_layers"] == 0
            else:
                assert row["accepted_per_dispatch"] >= 1.0
                assert row["draft_layers"] >= 1


def test_paged_engine_row_present(bench_doc):
    """The paged-KV trajectory row: block-table decode with a shared
    system prompt, so block reuse shows up in the memory columns."""
    paged = [row for row in bench_doc["rows"]
             if row["kind"] == "engine" and row["block_size"]]
    assert paged, "no paged engine row in the trajectory JSON"
    assert any(row["shared_block_hits"] > 0 for row in paged)
    assert any(row["prefill_tokens_skipped"] > 0 for row in paged)


def test_speculative_rows_beat_their_pair(bench_doc):
    """The perf story this PR ships: the speculative rows share their
    trace with a non-speculative row at the same (arch, rate), so the
    ticks column is directly comparable — a self-draft config must
    commit > 1 token per verify dispatch and finish the trace in
    strictly fewer engine ticks."""
    eng = [r for r in bench_doc["rows"] if r["kind"] == "engine"]
    spec = [r for r in eng if r["spec_k"] > 0]
    assert spec, "no speculative engine row in the trajectory JSON"
    assert any(r["accepted_per_dispatch"] > 1.0 for r in spec)
    for row in spec:
        pair = [r for r in eng
                if r["spec_k"] == 0 and r["arch"] == row["arch"]
                and r["rate"] == row["rate"]
                and r["n_requests"] == row["n_requests"]
                and not r["block_size"] and "+" not in r["arch"]]
        assert pair, f"speculative row has no non-spec pair: {row['arch']}"
        if row["accepted_per_dispatch"] > 1.0:
            assert row["ticks"] < min(r["ticks"] for r in pair), row


def test_multiplexed_rows_consolidate_occupancy(bench_doc):
    """The multi-model trajectory rows: two ``+dedicated`` rows (one
    engine per lane) and at least one ``+2model`` row (both lanes
    multiplexed) at the SAME per-model offered rates.  The multiplexed
    row must carry per-model columns for both lanes and beat either
    dedicated row's occupancy — the consolidation the shared slot lease
    exists for."""
    eng = [r for r in bench_doc["rows"] if r["kind"] == "engine"]
    ded = [r for r in eng if r["arch"].endswith("+dedicated")]
    mux = [r for r in eng if r["arch"].endswith("+2model")]
    assert mux, "no multiplexed engine row in the trajectory JSON"
    assert {r["model"] for r in ded} == {"a", "b"}
    for row in ded:
        # dedicated single-model engines have no per-model breakdown
        assert row["model_mean_occupancy"] == {}
        assert row["model_p99_s"] == {}
    for row in mux:
        assert row["model"] == "a+b"
        assert set(row["model_mean_occupancy"]) == {"a", "b"}
        assert set(row["model_p99_s"]) == {"a", "b"}
        assert all(v > 0 for v in row["model_p99_s"].values())
        assert all(v > 0 for v in
                   row["model_goodput_tokens_per_s"].values())
        # per-lane occupancy fractions share the leased-slot
        # denominator, so they sum to the row's combined occupancy
        assert sum(row["model_mean_occupancy"].values()) == \
            pytest.approx(row["mean_occupancy"])
        assert row["mean_occupancy"] > max(r["mean_occupancy"]
                                           for r in ded)
    # ordinary single-model rows stay unlabelled
    assert all(r["model"] is None for r in eng
               if "+dedicated" not in r["arch"]
               and "+2model" not in r["arch"])


def test_router_row_carries_fleet_columns(bench_doc):
    """The ``+router`` trajectory row: the same engine trace behind the
    replica router.  It must carry the fleet columns (replicas, tp,
    per-replica occupancy for every replica) while every ordinary row
    keeps the single-engine defaults — the schema change is invisible
    outside the fleet rows."""
    eng = [r for r in bench_doc["rows"] if r["kind"] == "engine"]
    routed = [r for r in eng if r["arch"].endswith("+router")]
    assert routed, "no +router engine row in the trajectory JSON"
    for row in routed:
        assert row["replicas"] >= 2 and row["tp"] >= 1
        assert len(row["replica_occupancy"]) == row["replicas"]
        assert all(0 < v <= 1 for v in row["replica_occupancy"].values())
        assert row["p99_s"] > 0 and row["tokens_per_s"] > 0
    for row in eng:
        if not row["arch"].endswith("+router"):
            assert row["replicas"] == 1 and row["tp"] == 1
            assert row["replica_occupancy"] == {}


def test_engine_rows_cover_all_decode_families(bench_doc):
    """The paper's all-NN-families serving argument: EVERY registry
    family serves through the slot engine and lands in the trajectory
    JSON — including encdec/vlm, whose rows decode behind per-slot
    primed cross-K/V (their ttft includes the prime dispatch)."""
    fams = {row["family"] for row in bench_doc["rows"]
            if row["kind"] == "engine"}
    assert {"dense", "moe", "ssm", "hybrid", "encdec", "vlm"} <= fams, fams
