"""Validation of the TPU v1 analytical model against the paper's numbers."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import perfmodel as pm


class TestHardwareConstants:
    def test_peak_tops(self):
        # 65,536 MACs x 700 MHz x 2 ops = 92 TOPS (paper headline)
        assert pm.TPU_V1.peak_ops / 1e12 == pytest.approx(92, rel=0.01)

    def test_ridge_point(self):
        # "operations per byte ... is ~1350" (paper §2 and Fig. 5)
        assert pm.TPU_V1.ridge_ops_per_byte == pytest.approx(1350, rel=0.01)

    def test_tile_fetch_is_ridge(self):
        # one 64 KiB tile fetch = the ridge in cycles — same quantity
        assert pm.TPU_V1.tile_fetch_cycles == pytest.approx(1350, rel=0.01)


class TestTable1:
    @pytest.mark.parametrize("app", pm.PAPER_APPS, ids=lambda a: a.name)
    def test_weight_counts(self, app):
        targets = {"MLP0": 20e6, "MLP1": 5e6, "LSTM0": 52e6,
                   "LSTM1": 34e6, "CNN0": 8e6, "CNN1": 100e6}
        assert app.weight_bytes == pytest.approx(targets[app.name],
                                                 rel=0.20)

    @pytest.mark.parametrize("app", pm.PAPER_APPS, ids=lambda a: a.name)
    def test_ops_per_byte(self, app):
        # Table 1 column "TPU Ops/Weight Byte"
        targets = {"MLP0": 200, "MLP1": 168, "LSTM0": 64, "LSTM1": 96,
                   "CNN0": 2888, "CNN1": 1750}
        assert app.ops_per_weight_byte == pytest.approx(
            targets[app.name], rel=0.05)


class TestTable3:
    def test_row9_tops_mean_error(self):
        """Model vs Table 3 row 9; paper's own model was within 8%
        (Table 7) — ours must be within 20% mean abs error."""
        errs = [abs(pm.simulate(a).tops / a.paper_tops - 1)
                for a in pm.PAPER_APPS]
        assert sum(errs) / len(errs) < 0.20

    def test_memory_bound_apps_have_high_stall(self):
        for name in ("MLP0", "MLP1", "LSTM0", "LSTM1"):
            r = pm.simulate(pm.APP_BY_NAME[name])
            assert r.stall_frac > 0.4, name      # Table 3 row 4: 44-62%
            assert r.active_frac < 0.2, name     # row 1: 8-13%

    def test_cnn0_compute_bound(self):
        r = pm.simulate(pm.APP_BY_NAME["CNN0"])
        assert r.active_frac > 0.6                # row 1: 78.2%
        assert r.stall_frac < 0.1                 # row 4: 0%


class TestFig11:
    def test_memory_is_biggest_lever(self):
        sw = pm.fig11_sweep()
        at4 = {k: dict(v)[4.0] for k, v in sw.items()}
        # "performance improves 3X on average when memory increases 4X"
        assert 2.5 < at4["memory"] < 4.0
        # "clock rate has little benefit"
        assert at4["clock"] < 1.3
        assert at4["clock+"] < 1.4
        # "average performance slightly degrades when the matrix unit
        # expands" (2x or 4x)
        assert at4["matrix"] < 1.0
        assert at4["matrix+"] < 1.0

    def test_lstm1_fragmentation_example(self):
        """Paper: 600-wide LSTM1 matrices tile worse on a 512 unit."""
        app = pm.APP_BY_NAME["LSTM1"]
        t256 = pm.simulate(app, pm.TPU_V1).time_s
        t512 = pm.simulate(app, pm.TPU_V1.scaled(matrix=2,
                                                 accumulators=4)).time_s
        assert t512 > t256 * 0.9   # no speedup from the bigger array


class TestTPUPrime:
    def test_gddr5_gains(self):
        g = pm.tpu_prime_gains()
        # paper: GM 2.6, WM 3.9 from GDDR5 alone (we accept a band)
        assert 2.0 < g["gddr5_gm"] < 3.5
        assert 3.0 < g["gddr5_wm"] < 5.5
        # clock alone: "almost no change"
        assert g["clock1.5_wm"] < 1.3
        # both: WM not much better than memory alone ("TPU' just has
        # faster memory")
        assert g["both_wm"] < g["gddr5_wm"] * 1.25

    def test_ridge_shift(self):
        # "shifting its roofline ridge point from 1350 to 250"
        assert pm.TPU_PRIME.ridge_ops_per_byte == pytest.approx(250, rel=0.02)


class TestModelProperties:
    @given(st.sampled_from([a.name for a in pm.PAPER_APPS]),
           st.floats(0.25, 4.0))
    @settings(max_examples=25, deadline=None)
    def test_more_bandwidth_never_hurts(self, name, s):
        app = pm.APP_BY_NAME[name]
        base = pm.simulate(app, pm.TPU_V1).time_s
        fast = pm.simulate(app, pm.TPU_V1.scaled(memory=s)).time_s
        if s >= 1:
            assert fast <= base * 1.001
        else:
            assert fast >= base * 0.999

    @given(st.integers(1, 2040))
    @settings(max_examples=25, deadline=None)
    def test_throughput_monotone_in_batch(self, b):
        """Monotone below the 2048-row accumulator capacity (the paper
        sized the UB 'to allow MLPs to run at batch sizes up to 2048')."""
        import dataclasses
        app = dataclasses.replace(pm.APP_BY_NAME["MLP0"], batch=b)
        app2 = dataclasses.replace(app, batch=b + 1)
        ips1 = pm.simulate(app).ips
        ips2 = pm.simulate(app2).ips
        assert ips2 >= ips1 * 0.999   # bigger batch never reduces IPS

    def test_accumulator_capacity_cliff(self):
        """Crossing 2048 rows forces a second chunk + weight re-fetch —
        the modeled analogue of overflowing the double-buffered
        accumulators."""
        import dataclasses
        at = pm.simulate(dataclasses.replace(pm.APP_BY_NAME["MLP0"],
                                             batch=2048)).ips
        over = pm.simulate(dataclasses.replace(pm.APP_BY_NAME["MLP0"],
                                               batch=2049)).ips
        assert over < at

    def test_roofline_attainable_bounds_achieved(self):
        for app in pm.PAPER_APPS:
            intensity, attain, achieved = pm.roofline_point(app)
            assert achieved <= attain * 1.001

    def test_counter_fractions_sum_to_one(self):
        for app in pm.PAPER_APPS:
            r = pm.simulate(app)
            total = (r.active_frac + r.stall_frac + r.shift_frac
                     + r.nonmatrix_frac)
            assert total == pytest.approx(1.0, abs=1e-6)


def test_unified_buffer_within_capacity():
    """Table 8: every app fits the 24 MiB Unified Buffer."""
    for app in pm.PAPER_APPS:
        assert pm.unified_buffer_mib(app) < 24.0
