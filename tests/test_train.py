"""Training integration: loss decreases, optimizers step, resume works."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import registry as R
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_schedule,
                         make_optimizer)
from repro.runtime import steps as ST

KEY = jax.random.PRNGKey(0)


class TestOptimizers:
    def _quadratic(self, opt_name):
        """Both optimizers must drive a quadratic toward its minimum."""
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros((16, 3))}
        opt = make_optimizer(opt_name, lr=0.05, weight_decay=0.0)
        state = opt.init(params)

        def loss_fn(p):
            return jnp.mean((p["w"] - target[None]) ** 2)
        for _ in range(200):
            g = jax.grad(loss_fn)(params)
            params, state = opt.update(params, g, state)
        return float(loss_fn(params))

    def test_adamw_converges(self):
        assert self._quadratic("adamw") < 1e-2

    def test_adafactor_converges(self):
        assert self._quadratic("adafactor") < 1e-2

    def test_adafactor_memory_factored(self):
        p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
        st = adafactor_init(p)
        assert st.vr["w"].shape == (64,)     # row moments
        assert st.vc["w"].shape == (32,)     # col moments
        assert st.vr["b"].shape == (32,)     # small leaf: full

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
        assert float(total) == pytest.approx(1.0, rel=1e-5)

    def test_cosine_schedule(self):
        lr = cosine_schedule(1e-3, warmup=10, total=100)
        assert float(lr(jnp.array(0))) == 0.0
        assert float(lr(jnp.array(10))) == pytest.approx(1e-3, rel=1e-3)
        assert float(lr(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)

    def test_adamw_master_weights(self):
        p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
        st = adamw_init(p, keep_master=True)
        assert st.master["w"].dtype == jnp.float32


class TestTrainLoop:
    @pytest.mark.parametrize("arch", ["starcoder2-3b", "mamba2-1.3b"])
    def test_loss_decreases(self, arch):
        cfg = get_config(arch).reduced()
        params = R.init(KEY, cfg)
        opt = make_optimizer("adamw", lr=3e-3)
        state = opt.init(params)
        step = jax.jit(ST.make_train_step(cfg, opt),
                       donate_argnums=(0, 1))
        data = SyntheticLMData(cfg.vocab, 32, 8, seed=0)
        losses = []
        for t in range(30):
            tokens, labels = data.batch_at(t)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            params, state, m = step(params, state, batch,
                                    jax.random.fold_in(KEY, t))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses

    def test_cross_entropy_values(self):
        logits = jnp.log(jnp.array([[[0.7, 0.2, 0.1]]]))
        labels = jnp.array([[0]])
        ce = ST.cross_entropy(logits, labels, z_loss=0.0)
        assert float(ce) == pytest.approx(-np.log(0.7), rel=1e-5)

    def test_train_launcher_end_to_end(self, tmp_path):
        """launch.train main(): run, kill, resume — full FT story."""
        from repro.launch import train as TR
        args = ["--arch", "starcoder2-3b", "--reduced", "--steps", "12",
                "--seq-len", "32", "--batch", "4", "--ckpt-dir",
                str(tmp_path), "--ckpt-every", "5", "--log-every", "50"]
        assert TR.main(args) == 0
        # resume: picks up from step 10 (the newest committed checkpoint)
        rc = TR.main(args + ["--resume", "auto"])
        assert rc == 0


class TestServeSteps:
    def test_prefill_and_decode(self):
        cfg = get_config("mistral-nemo-12b").reduced()
        params = R.init(KEY, cfg)
        prefill = jax.jit(ST.make_prefill_step(cfg))
        batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab)}
        logits = prefill(params, batch)
        assert logits.shape == (2, 8, cfg.vocab)
        decode = jax.jit(ST.make_decode_step(cfg))
        cache = R.init_cache(cfg, 2, 32)
        d = {"tokens": batch["tokens"][:, :1],
             "cache_index": jnp.array(0)}
        lg, cache2 = decode(params, d, cache)
        assert lg.shape == (2, 1, cfg.vocab)

    def test_sampling(self):
        logits = jnp.zeros((2, 1, 16)).at[:, -1, 5].set(10.0)
        assert list(np.asarray(ST.greedy_sample(logits))) == [5, 5]
        s = ST.temperature_sample(logits, KEY, temperature=0.5)
        assert s.shape == (2,)
