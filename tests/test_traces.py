"""Workload synthesis (`benchmarks/traces.py`): determinism, burstiness,
heavy tails, the two-class trace, and the `synthetic_requests`
passthrough whose defaults must stay byte-identical to today's traces."""
import pytest

from benchmarks import traces as TR
from repro import engine as E


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

class TestArrivals:
    def test_mmpp_deterministic(self):
        proc = TR.mmpp_process()
        assert proc(50, 100.0, 3) == proc(50, 100.0, 3)
        assert proc(50, 100.0, 3) != proc(50, 100.0, 4)

    def test_mmpp_sorted_and_sized(self):
        times = TR.mmpp_process()(100, 200.0, 0)
        assert len(times) == 100
        assert all(b >= a for a, b in zip(times, times[1:]))

    def test_mmpp_is_overdispersed_vs_poisson(self):
        """The burstiness statistic: MMPP arrival counts have variance
        well above their mean; Poisson counts sit near IoD = 1."""
        rate = 200.0
        mmpp = TR.mmpp_process(dwell_s=(0.5, 0.125))(400, rate, 0)
        pois = TR.poisson_process()(400, rate, 0)
        assert TR.index_of_dispersion(mmpp) > 2.0
        assert TR.index_of_dispersion(pois) < 2.0

    def test_mmpp_validates_parameters(self):
        with pytest.raises(ValueError):
            TR.mmpp_process(modulation=(1.0, 2.0, 3.0))
        with pytest.raises(ValueError):
            TR.mmpp_process(dwell_s=(0.0, 0.1))

    def test_index_of_dispersion_edge_cases(self):
        assert TR.index_of_dispersion([]) == 0.0
        assert TR.index_of_dispersion([0.1]) >= 0.0

    def test_diurnal_deterministic(self):
        proc = TR.diurnal_process()
        assert proc(50, 100.0, 3) == proc(50, 100.0, 3)
        assert proc(50, 100.0, 3) != proc(50, 100.0, 4)

    def test_diurnal_sorted_and_sized(self):
        times = TR.diurnal_process()(100, 200.0, 0)
        assert len(times) == 100
        assert all(b >= a for a, b in zip(times, times[1:]))

    def test_diurnal_is_overdispersed_vs_poisson(self):
        """Counts on windows shorter than the period are overdispersed
        (peak slices arrive ~(1+depth)/(1-depth)x faster than troughs);
        depth=0 degenerates to plain Poisson and IoD drops back to ~1."""
        rate = 200.0
        diur = TR.diurnal_process(depth=0.9, period_s=2.0)(400, rate, 0)
        flat = TR.diurnal_process(depth=0.0, period_s=2.0)(400, rate, 0)
        assert TR.index_of_dispersion(diur) > 2.0
        assert TR.index_of_dispersion(flat) < 2.0

    def test_diurnal_peak_half_outpaces_trough_half(self):
        """With phase=0 the first half-period is the high-rate half of
        the sinusoid: it must hold clearly more arrivals than the second
        half on a period-long horizon."""
        period = 1.0
        times = TR.diurnal_process(depth=0.8, period_s=period)(
            300, 300.0, 1)
        first = sum(1 for t in times if t % period < period / 2)
        second = sum(1 for t in times if t % period >= period / 2)
        assert first > 1.5 * second

    def test_diurnal_validates_parameters(self):
        with pytest.raises(ValueError):
            TR.diurnal_process(depth=1.0)
        with pytest.raises(ValueError):
            TR.diurnal_process(depth=-0.1)
        with pytest.raises(ValueError):
            TR.diurnal_process(period_s=0.0)
        with pytest.raises(ValueError):
            TR.diurnal_process(steps_per_period=1)


# ---------------------------------------------------------------------------
# heavy-tailed lengths
# ---------------------------------------------------------------------------

class TestLengths:
    def test_bounded_and_deterministic(self):
        a = TR.heavy_tailed_lengths(500, lo=2, hi=64, seed=1)
        assert a == TR.heavy_tailed_lengths(500, lo=2, hi=64, seed=1)
        assert all(2 <= x <= 64 for x in a)

    def test_tail_shape(self):
        """Most mass near lo, but the tail actually reaches out — the
        bounded-Pareto shape, not uniform."""
        a = TR.heavy_tailed_lengths(2000, lo=2, hi=64, alpha=1.6, seed=0)
        assert sum(1 for x in a if x <= 8) > len(a) * 0.6
        assert max(a) > 32

    def test_validates(self):
        with pytest.raises(ValueError):
            TR.heavy_tailed_lengths(5, lo=0, hi=4)
        with pytest.raises(ValueError):
            TR.heavy_tailed_lengths(5, lo=2, hi=4, alpha=0.0)


# ---------------------------------------------------------------------------
# the two-class trace
# ---------------------------------------------------------------------------

class TestTwoClassTrace:
    def test_deterministic_and_typed(self):
        a = TR.two_class_trace(60, rate_per_s=500.0, vocab=97, seed=2)
        b = TR.two_class_trace(60, rate_per_s=500.0, vocab=97, seed=2)
        assert a == b
        assert all(r.priority in ("interactive", "batch") for r in a)
        assert all(1 <= t < 97 for r in a for t in r.prompt)

    def test_class_mix_and_deadlines(self):
        reqs = TR.two_class_trace(200, rate_per_s=500.0, vocab=97,
                                  interactive_frac=0.7,
                                  interactive_deadline_s=0.25,
                                  batch_deadline_s=8.0)
        n_int = sum(r.priority == "interactive" for r in reqs)
        assert 0.55 < n_int / len(reqs) < 0.85
        for r in reqs:
            gap = r.deadline_s - r.arrival_s
            want = 0.25 if r.priority == "interactive" else 8.0
            assert gap == pytest.approx(want)

    def test_validates_frac(self):
        with pytest.raises(ValueError):
            TR.two_class_trace(5, rate_per_s=1.0, vocab=7,
                               interactive_frac=1.5)


# ---------------------------------------------------------------------------
# synthetic_requests passthrough
# ---------------------------------------------------------------------------

class TestSyntheticPassthrough:
    def test_defaults_byte_identical(self):
        """The new priority=/arrival_process= knobs must not move the
        default trace by a single byte (every existing test and BENCH
        row depends on it)."""
        base = E.synthetic_requests(20, rate_per_s=1000.0, vocab=97)
        tagged = E.synthetic_requests(20, rate_per_s=1000.0, vocab=97,
                                      priority="interactive",
                                      arrival_process=None)
        assert base == tagged
        assert all(r.priority == "interactive" for r in base)

    def test_priority_callable(self):
        reqs = E.synthetic_requests(
            10, rate_per_s=1000.0, vocab=97,
            priority=lambda rid: "batch" if rid % 2 else "interactive")
        assert [r.priority for r in reqs] == \
            ["interactive", "batch"] * 5

    def test_custom_arrival_process(self):
        """A custom process replaces the arrival times but nothing else
        — prompts stay rid-derived and identical to the default trace."""
        proc = TR.mmpp_process(dwell_s=(0.01, 0.005))
        reqs = E.synthetic_requests(12, rate_per_s=1000.0, vocab=97,
                                    arrival_process=proc)
        base = E.synthetic_requests(12, rate_per_s=1000.0, vocab=97)
        assert [r.arrival_s for r in reqs] == proc(12, 1000.0, 0)
        assert [r.prompt for r in reqs] == [r.prompt for r in base]

    def test_arrival_process_validated(self):
        with pytest.raises(ValueError, match="sorted"):
            E.synthetic_requests(
                3, rate_per_s=1.0, vocab=7,
                arrival_process=lambda n, r, s: [3.0, 2.0, 1.0])
        with pytest.raises(ValueError, match="sorted"):
            E.synthetic_requests(
                3, rate_per_s=1.0, vocab=7,
                arrival_process=lambda n, r, s: [1.0])
