"""Quantization unit + property tests (the paper's numerical contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # offline: no network, no pip
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import quant as Q


class TestQuantizeRoundTrip:
    def test_per_tensor_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        q = Q.quantize(x, bits=8, axis=None)
        err = jnp.abs(q.dequantize() - x)
        # symmetric rounding: |err| <= scale/2 everywhere
        assert float(jnp.max(err)) <= float(q.scale) * 0.5 + 1e-7

    def test_per_channel_tighter_than_per_tensor(self):
        key = jax.random.PRNGKey(1)
        # one channel with 100x the scale of the others
        x = jax.random.normal(key, (128, 16))
        x = x.at[:, 3].mul(100.0)
        q_t = Q.quantize(x, bits=8, axis=None)
        q_c = Q.quantize_weight(x, bits=8)
        err_t = float(jnp.mean(jnp.abs(q_t.dequantize() - x)[:, :3]))
        err_c = float(jnp.mean(jnp.abs(q_c.dequantize() - x)[:, :3]))
        assert err_c < err_t / 10

    def test_int_bounds_symmetric(self):
        lo, hi = Q.int_bounds(8)
        assert (lo, hi) == (-127, 127)

    def test_values_in_range(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (32, 32)) * 50
        q = Q.quantize(x, bits=8)
        assert int(jnp.max(q.values)) <= 127
        assert int(jnp.min(q.values)) >= -127

    @given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_error_bound_property(self, seed, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (16, 8)) * scale
        q = Q.quantize(x, bits=8, axis=None)
        err = jnp.max(jnp.abs(q.dequantize() - x))
        assert float(err) <= float(q.scale) * 0.5 + 1e-6 * scale

    def test_fake_quant_gradient_straight_through(self):
        x = jnp.array([0.5, -1.0, 2.0])
        g = jax.grad(lambda v: jnp.sum(Q.fake_quant(v) * 3.0))(x)
        np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-6)


class TestQuantizeTree:
    def _params(self):
        k = jax.random.PRNGKey(0)
        return {
            "layers": {
                "attn": {"wq": {"w": jax.random.normal(k, (4, 128, 128)),
                                "b": jnp.zeros((4, 128))}},
                "ln_attn": {"scale": jnp.ones((4, 128))},
            },
            "embed": {"table": jax.random.normal(k, (512, 64))},
        }

    def test_allowlist(self):
        qp = Q.quantize_tree(self._params(), min_size=1024)
        assert isinstance(qp["layers"]["attn"]["wq"]["w"], Q.QTensor)
        assert isinstance(qp["embed"]["table"], Q.QTensor)
        # biases and norm scales must stay fp
        assert not isinstance(qp["layers"]["attn"]["wq"]["b"], Q.QTensor)
        assert not isinstance(qp["layers"]["ln_attn"]["scale"], Q.QTensor)

    def test_stacked_scales_scannable(self):
        qp = Q.quantize_tree(self._params(), min_size=1024)
        w = qp["layers"]["attn"]["wq"]["w"]
        assert w.values.shape == (4, 128, 128)
        assert w.scale.shape == (4, 1, 128)   # per-layer, per-column

    def test_embedding_per_row(self):
        qp = Q.quantize_tree(self._params(), min_size=1024)
        t = qp["embed"]["table"]
        assert t.scale.shape == (512, 1)

    def test_weight_bytes_halve_vs_fp32(self):
        p = self._params()
        fp_bytes = Q.tree_weight_bytes(p)
        q_bytes = Q.tree_weight_bytes(Q.quantize_tree(p, min_size=1024))
        assert q_bytes < fp_bytes / 2.5   # int8 + small fp leaves


class TestGradientCompression:
    def test_unbiased(self):
        g = jax.random.normal(jax.random.PRNGKey(3), (256,))
        keys = jax.random.split(jax.random.PRNGKey(4), 300)
        acc = jnp.zeros_like(g)
        for k in keys:
            acc = acc + Q.compress_gradient(g, k).dequantize()
        mean = acc / len(keys)
        # stochastic rounding is unbiased: mean converges to g
        assert float(jnp.max(jnp.abs(mean - g))) < float(
            Q.compute_scale(g)) * 0.25

    def test_qtensor_is_pytree_with_keys(self):
        q = Q.quantize(jnp.ones((8, 8)), bits=8)
        flat = jax.tree_util.tree_flatten_with_path(q)[0]
        names = {str(p[-1]) for p, _ in flat}
        assert names == {".values", ".scale"}


def test_bits_speed_factor():
    assert Q.bits_speed_factor(8, 8) == 1.0
    assert Q.bits_speed_factor(8, 16) == 0.5
    assert Q.bits_speed_factor(16, 16) == 0.25


class TestInt4:
    """int4 weight-only quantization (stored in int8 containers, like
    XLA:TPU packs narrow ints) through the same kernel path."""

    def test_int4_bounds(self):
        assert Q.int_bounds(4) == (-7, 7)

    def test_int4_roundtrip_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
        q = Q.quantize(x, bits=4, axis=None)
        assert int(jnp.max(jnp.abs(q.values))) <= 7
        err = jnp.max(jnp.abs(q.dequantize() - x))
        assert float(err) <= float(q.scale) * 0.5 + 1e-6

    def test_int4_matmul_through_kernel(self):
        from repro.kernels import ops
        keys = jax.random.split(jax.random.PRNGKey(1), 2)
        x = jax.random.normal(keys[0], (64, 128))
        w_fp = jax.random.normal(keys[1], (128, 64))
        w4 = Q.quantize_weight(w_fp, bits=4)
        got = ops.qmatmul(x, w4, None, interpret=True,
                          out_dtype=jnp.float32)
        rel = float(jnp.linalg.norm(got - x @ w_fp)
                    / jnp.linalg.norm(x @ w_fp))
        # symmetric int4 (±7 levels) on N(0,1) weights: expected rel err is
        # scale/sqrt(12) with scale = max|w|/7 ~ 3.2/7, i.e. ~0.13
        assert rel < 0.14   # 4-bit: ~16x coarser than int8

    def test_int4_weight_bytes(self):
        w = Q.quantize_weight(jnp.ones((256, 256)), bits=4)
        # nbytes_weights models the 4-bit wire format (packed)
        assert w.nbytes_weights < 256 * 256 * 1 + 256 * 8
