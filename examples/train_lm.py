"""End-to-end training driver: ~100M-parameter LM for a few hundred steps.

A mid-size decoder-only config (same family as starcoder2) trained on the
synthetic pipeline with checkpointing + resume — kill it and rerun to see
the fault-tolerance path.  On CPU this takes a few minutes; the same script
drives the production mesh on a real pod via launch/train.py.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLMData
from repro.models import registry as R
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime import steps as ST
from repro.runtime.watchdog import StepTimer, StepWatchdog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12L, d=768, ff=3072, vocab 32768 (GPT-2-small scale)
    cfg = dataclasses.replace(
        get_config("starcoder2-3b"),
        name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=3072, vocab=32768, head_dim=64)
    key = jax.random.PRNGKey(0)
    params = R.init(key, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    opt = make_optimizer("adamw",
                         lr=cosine_schedule(3e-4, 50, args.steps))
    state = opt.init(params)
    step = jax.jit(ST.make_train_step(cfg, opt), donate_argnums=(0, 1))
    data = SyntheticLMData(cfg.vocab, args.seq_len, args.batch, seed=0)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start, restored = ckpt.restore_latest({"params": params, "opt": state})
    if start is not None:
        params, state = restored["params"], restored["opt"]
        print(f"[resume] from step {start}")
    start = start or 0

    watchdog = StepWatchdog()
    losses = []
    for t in range(start, args.steps):
        tokens, labels = data.batch_at(t)
        batch = {"tokens": jnp.asarray(tokens),
                 "labels": jnp.asarray(labels)}
        with StepTimer() as timer:
            params, state, m = step(params, state, batch,
                                    jax.random.fold_in(key, t))
            loss = float(m["loss"])
        losses.append(loss)
        warn = watchdog.record(timer.elapsed)
        if warn:
            print(f"  [watchdog] {warn}")
        if t % 25 == 0:
            tps = args.batch * args.seq_len / max(timer.elapsed, 1e-9)
            print(f"step {t:4d}  loss {loss:.3f}  "
                  f"{timer.elapsed*1e3:6.0f} ms  {tps:,.0f} tok/s")
        if (t + 1) % 100 == 0:
            ckpt.save_async(t + 1, {"params": params, "opt": state},
                            metadata={"data_step": t + 1})
    ckpt.wait()
    print(f"final: loss {np.mean(losses[:5]) if len(losses)>=5 else 0:.3f}"
          f" -> {np.mean(losses[-5:]):.3f}")


if __name__ == "__main__":
    main()
