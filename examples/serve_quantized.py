"""Quantized batched serving under a p99 deadline — the paper's production
scenario on the six NN apps it benchmarked (MLP0/1, LSTM0/1, CNN0/1).

For each app: build the model at Table 1 scale, quantize to int8, measure
the service-time curve of the jitted step, pick the largest batch meeting
the app's deadline (Table 4 policy), then push a pseudo-Poisson request
stream through the BatchQueue and report p99 / throughput.

Run:  PYTHONPATH=src python examples/serve_quantized.py [--apps MLP0,MLP1]
"""
import argparse
import os
import sys
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_apps import PAPER_APP_CONFIGS
from repro.core import batching as bt
from repro.core.qlinear import W8A16
from repro.core.quant import quantize_tree, tree_weight_bytes
from repro.models import paper_nets as PN


def measure(app_cfg, params, batches=(1, 8, 32), iters=3):
    fn = jax.jit(lambda p, x: PN.apply_app(p, app_cfg, x, mode=W8A16))
    times = {}
    for b in batches:
        x = PN.app_input(app_cfg, batch=b)
        fn(params, x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(params, x).block_until_ready()
        times[b] = (time.perf_counter() - t0) / iters
    bs = sorted(times)
    per = max((times[bs[-1]] - times[bs[0]]) / (bs[-1] - bs[0]), 1e-9)
    fixed = max(times[bs[0]] - bs[0] * per, 1e-9)
    return bt.LatencyModel("local", fixed * 2, per * 1.5, fixed, per)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", default="MLP0,MLP1,LSTM1")
    ap.add_argument("--n-requests", type=int, default=150)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    for name in args.apps.split(","):
        cfg = PAPER_APP_CONFIGS[name]
        params = PN.init_app(key, cfg)
        fp_mb = tree_weight_bytes(params) / 1e6
        qparams = quantize_tree(params, min_size=1024)
        q_mb = tree_weight_bytes(qparams) / 1e6
        model = measure(cfg, qparams)
        # deadline: generous multiple of single-item service on CPU
        deadline = max(cfg.deadline_ms * 1e-3, model.p99_latency(8))
        batch = bt.choose_batch(model, deadline, max_batch=cfg.batch)
        reqs = bt.poisson_arrivals(0.5 * batch / model.service_time(batch),
                                   args.n_requests, deadline)
        recs = bt.BatchQueue(model.service_time, max_batch=batch).run(reqs)
        arrival = {r.rid: r.arrival_s for r in reqs}
        lat = [rec.finish_s - arrival[rid] for rec in recs
               for rid in rec.rids]
        print(f"{name:6s} weights {fp_mb:6.1f}->{q_mb:6.1f} MB | "
              f"batch={batch:3d} (paper used {cfg.batch}) | "
              f"p99 {bt.p99(lat)*1e3:7.2f} ms (deadline "
              f"{deadline*1e3:6.1f} ms) | "
              f"{len(lat)/max(r.finish_s for r in recs):7.1f} req/s | "
              f"deadline met {np.mean([r.deadlines_met for r in recs]):.0%}")


if __name__ == "__main__":
    main()
