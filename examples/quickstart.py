"""Quickstart: the paper's full workflow in one script, CPU-runnable.

1. train a small LM (fp32/bf16),
2. post-training int8 quantization (the paper's technique),
3. latency-bounded batched serving (Table 4 policy),
4. the TPU v1 analytical model: roofline + design sweep highlights.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import batching as bt
from repro.core import perfmodel as pm
from repro.core.qlinear import W8A16
from repro.core.quant import quantize_tree, tree_weight_bytes
from repro.data import SyntheticLMData
from repro.models import registry as R
from repro.optim import make_optimizer
from repro.runtime import steps as ST


def main():
    key = jax.random.PRNGKey(0)
    cfg = get_config("starcoder2-3b").reduced()
    print(f"== 1. train {cfg.name} ({cfg.n_layers}L d={cfg.d_model}) ==")
    params = R.init(key, cfg)
    opt = make_optimizer("adamw", lr=3e-3)
    state = opt.init(params)
    step = jax.jit(ST.make_train_step(cfg, opt), donate_argnums=(0, 1))
    data = SyntheticLMData(cfg.vocab, 64, 8, seed=0)
    losses = []
    for t in range(40):
        tokens, labels = data.batch_at(t)
        batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
        params, state, m = step(params, state, batch,
                                jax.random.fold_in(key, t))
        losses.append(float(m["loss"]))
        if t % 10 == 0:
            print(f"  step {t:3d}  loss {losses[-1]:.3f}")
    print(f"  loss {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f}")

    print("== 2. post-training int8 quantization ==")
    fp_bytes = tree_weight_bytes(params)
    qparams = quantize_tree(params, min_size=2048)
    print(f"  weights {fp_bytes/1e6:.1f} MB -> "
          f"{tree_weight_bytes(qparams)/1e6:.1f} MB")
    tokens, _ = data.batch_at(99)
    b = {"tokens": jnp.asarray(tokens)}
    fp = R.apply_forward(params, cfg, b)
    qi = R.apply_forward(qparams, cfg, b, mode=W8A16)
    agree = float(jnp.mean(jnp.argmax(fp, -1) == jnp.argmax(qi, -1)))
    print(f"  int8 vs fp top-1 agreement: {agree:.1%}")

    print("== 3. latency-bounded serving (Table 4 policy) ==")
    for model, cap in ((bt.TABLE4_CPU, 64), (bt.TABLE4_GPU, 64),
                       (bt.TABLE4_TPU, 250)):
        bsz, lat, ips, frac = bt.table4_row(model, 7e-3, max_batch=cap)
        print(f"  {model.name:8s} batch={bsz:4d} p99={lat*1e3:5.1f} ms "
              f"IPS={ips:9,.0f} ({frac:.0%} of max)")

    print("== 4. TPU v1 analytical model highlights ==")
    print(f"  peak {pm.TPU_V1.peak_ops/1e12:.0f} TOPS, ridge "
          f"{pm.TPU_V1.ridge_ops_per_byte:.0f} ops/byte (paper: 92, ~1350)")
    for name in ("MLP0", "CNN0"):
        r = pm.simulate(pm.APP_BY_NAME[name])
        print(f"  {name}: modeled {r.tops:.1f} TOPS "
              f"(paper {pm.APP_BY_NAME[name].paper_tops})")
    g = pm.tpu_prime_gains()
    print(f"  TPU' (GDDR5): GM {g['gddr5_gm']:.1f}x / WM "
          f"{g['gddr5_wm']:.1f}x (paper: 2.6 / 3.9)")


if __name__ == "__main__":
    main()
