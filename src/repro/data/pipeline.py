"""Deterministic synthetic-LM data pipeline.

Properties the large-scale runtime needs (and tests assert):

- **Deterministic & stateless-resumable**: batch at step t is a pure
  function of (seed, step) — resuming from a checkpointed step reproduces
  the exact stream, so checkpoint/restart does not replay or skip data.
- **Host-sharded**: each host materializes only its slice of the global
  batch (``host_slice``); the global batch is assembled by the sharded
  donation to jit, never on one host.
- **Static shapes**: every batch is (B, S) int32 — no recompilation, which
  is also the straggler-mitigation story (deterministic step times).

The token distribution is a mixture of Zipfian unigrams and repeated
n-gram motifs so the LM loss has learnable structure (quickstart shows a
decreasing loss), while needing no external data.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataState:
    seed: int
    step: int

    def next(self) -> "DataState":
        return DataState(self.seed, self.step + 1)


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64

    def _motifs(self) -> np.ndarray:
        rng = np.random.RandomState(self.seed ^ 0x5EED)
        return rng.randint(1, self.vocab,
                           size=(self.n_motifs, self.motif_len))

    def batch_at(self, step: int, *, host_index: int = 0,
                 host_count: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for this host's slice of global step `step`."""
        assert self.global_batch % host_count == 0
        per_host = self.global_batch // host_count
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step) % (2**31 - 1))
        # all hosts draw the global batch identically, then slice: cheap at
        # these sizes and keeps the stream independent of topology.
        zipf = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        tokens = np.minimum(zipf, self.vocab - 1).astype(np.int32)
        motifs = self._motifs()
        n_insert = max(1, self.seq_len // (4 * self.motif_len))
        for b in range(self.global_batch):
            for _ in range(n_insert):
                m = motifs[rng.randint(self.n_motifs)]
                start = rng.randint(0, self.seq_len + 1 - self.motif_len)
                tokens[b, start:start + self.motif_len] = m
        lo = host_index * per_host
        sl = tokens[lo:lo + per_host]
        return sl[:, :-1], sl[:, 1:]

    def iterate(self, state: DataState, *, host_index: int = 0,
                host_count: int = 1) -> Iterator:
        while True:
            yield self.batch_at(state.step, host_index=host_index,
                                host_count=host_count), state
            state = state.next()


def make_pipeline(cfg, shape, seed: int = 0) -> SyntheticLMData:
    return SyntheticLMData(vocab=cfg.vocab, seq_len=shape.seq_len,
                           global_batch=shape.global_batch, seed=seed)
