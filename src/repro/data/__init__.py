from repro.data.pipeline import (SyntheticLMData, DataState, make_pipeline)

__all__ = ["SyntheticLMData", "DataState", "make_pipeline"]
