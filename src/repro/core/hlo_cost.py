"""Trip-count-aware cost analysis over post-SPMD HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, so any scan-over-layers model (all of ours) under-reports FLOPs/bytes
by ~n_layers — useless for rooflines.  This module re-derives the three
roofline inputs by walking the HLO text recursively:

- flops: dot (2 * result_elems * contraction) and convolution ops, found in
  any computation including inside fusions, multiplied up through while-loop
  trip counts (parsed from the loop condition's comparison constant — JAX
  scans always count 0..N);
- bytes: XLA's bytes-accessed convention at *fusion boundaries*
  (sum of operand + result sizes for every materializing op), so
  register/VMEM reuse inside a fusion is not double-counted;
- collective bytes: operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, also trip-multiplied.

The compiled module is the per-device program (shapes are shard shapes), so
totals are per-chip; callers multiply by chip count for the global figure.

Known approximations (documented, conservative):
- elementwise/transcendental flops ignored (matmul-dominated workloads);
- `conditional` branches take the max-cost branch;
- a while whose bound cannot be parsed contributes trip=1 (warned in the
  result so it is visible rather than silent).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u2": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# one typed shape, e.g. bf16[8,128]{1,0} or f32[] or (tuples handled apart)
_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|\S+(?:\[[\d,]*\])?"
    r"(?:\{[\d,]*\})?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->")


def _shape_list(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str            # operand list + attrs (raw tail of the line)

    def operands(self) -> List[str]:
        # rest begins AFTER the opcode's opening paren -> depth starts at 1
        depth, args, cur = 1, [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                args.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
        if cur and "".join(cur).strip():
            args.append("".join(cur).strip())
        names = []
        for a in args:
            a = a.strip()
            m = re.search(r"%([\w\.\-]+)\s*$", a)
            names.append(m.group(1) if m else a.lstrip("%"))
        return names

    def attr(self, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str) -> List[int]:
        m = re.search(key + r"=\{([\d,]*)\}", self.rest)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]
    param_types: Dict[str, str]

    def shape_of(self, operand: str) -> Optional[str]:
        if operand in self.instrs:
            return self.instrs[operand].type_str
        return self.param_types.get(operand)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                params = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                      m.group(2)):
                    params[pm.group(1)] = pm.group(2).strip()
                cur = Computation(m.group(1), {}, [], params)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            ins = Instr(im.group(1), im.group(2), im.group(3), im.group(4))
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    if cur is not None:
        comps[cur.name] = cur
    return comps


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "custom-call",
}

# Ops that are pure element-wise dataflow: on TPU these fuse into the
# producing/consuming matmul or reduction kernel, so in the tpu-fused byte
# model a fusion containing ONLY these contributes no extra HBM traffic
# (its bytes are already counted at the neighbouring matmul/reduce/copy).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "sign",
    "maximum", "minimum", "compare", "select", "and", "or", "xor", "not",
    "convert", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "power", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "is-finite",
    "broadcast", "reshape", "bitcast", "copy", "transpose", "iota",
    "constant", "parameter", "tuple", "get-tuple-element", "slice", "pad",
    "concatenate", "reverse", "erf", "atan2", "expm1", "log1p", "real",
    "imag", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "stochastic-convert", "reduce-precision",
    "bitcast-convert", "popcnt", "clz", "map",
}


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVE_OPS})
    unparsed_whiles: int = 0

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult
        self.unparsed_whiles += other.unparsed_whiles


class HloCostModel:
    """mode="tpu-fused" (default): bytes use the TPU fusion model — pure
    element-wise fusions are free (they fuse into neighbours on TPU), while
    matmuls, reductions, (dynamic-)slices/updates, gathers/scatters, copies
    and collectives pay operand+result traffic.  mode="raw": every CPU
    fusion boundary pays (XLA bytes-accessed convention on this backend) —
    reported alongside for transparency."""

    def __init__(self, text: str, mode: str = "tpu-fused"):
        self.comps = parse_hlo(text)
        self.mode = mode
        self._cache: Dict[str, CostTotals] = {}
        self._fusion_free: Dict[str, bool] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line)
                entry = m.group(1) if m else None
                break
        if entry is None:  # fall back: last computation
            entry = list(self.comps)[-1]
        self.entry = entry

    def _is_elementwise_only(self, comp_name: str) -> bool:
        """True if the computation (and its callees) contain only
        element-wise dataflow ops."""
        if comp_name in self._fusion_free:
            return self._fusion_free[comp_name]
        comp = self.comps.get(comp_name)
        ok = True
        if comp is not None:
            for iname in comp.order:
                ins = comp.instrs[iname]
                if ins.opcode == "fusion":
                    callee = ins.attr("calls")
                    if callee and not self._is_elementwise_only(callee):
                        ok = False
                        break
                    continue
                if ins.opcode not in _ELEMENTWISE:
                    ok = False
                    break
        self._fusion_free[comp_name] = ok
        return ok

    # -- per-op costs -----------------------------------------------------

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        shapes = _shape_list(ins.type_str)
        if not shapes:
            return 0.0
        out_elems = _elems(shapes[0][1])
        ops = ins.operands()
        lhs_shape = comp.shape_of(ops[0]) if ops else None
        contract = 1
        if lhs_shape:
            ls = _shape_list(lhs_shape)
            if ls:
                dims = ls[0][1]
                cdims = ins.attr_list("lhs_contracting_dims")
                for c in cdims:
                    if c < len(dims):
                        contract *= dims[c]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        shapes = _shape_list(ins.type_str)
        if not shapes:
            return 0.0
        out_elems = _elems(shapes[0][1])
        ops = ins.operands()
        if len(ops) < 2:
            return 0.0
        rhs_shape = comp.shape_of(ops[1])
        if not rhs_shape:
            return 0.0
        rs = _shape_list(rhs_shape)
        if not rs:
            return 0.0
        kernel_elems = _elems(rs[0][1])
        out_feat = rs[0][1][-1] if rs[0][1] else 1
        return 2.0 * out_elems * (kernel_elems / max(1, out_feat))

    def _op_bytes(self, comp: Computation, ins: Instr) -> float:
        """Operand+result bytes with slice-aware charging.

        A dynamic-slice reads only its result-sized window, not the whole
        operand (critical: scan-saved activation stacks (L, B, S, D) and
        stacked layer weights are consumed one layer-slice per iteration).
        Likewise dynamic-update-slice writes only the update region
        (in-place KV-cache updates).  Fusion operands consumed exclusively
        via dynamic-slice inside the fusion are charged at slice size.
        """
        op = ins.opcode
        if op == "dynamic-slice":
            return 2.0 * _type_bytes(ins.type_str)
        if op == "dynamic-update-slice":
            ops = ins.operands()
            upd = comp.shape_of(ops[1]) if len(ops) > 1 else None
            if upd:
                return 2.0 * _type_bytes(upd)
            return float(_type_bytes(ins.type_str))
        if op == "gather":
            return 2.0 * _type_bytes(ins.type_str)
        if op == "scatter":
            ops = ins.operands()
            upd = comp.shape_of(ops[2]) if len(ops) > 2 else None
            return 2.0 * _type_bytes(upd) if upd else \
                float(_type_bytes(ins.type_str))
        callee = ins.attr("calls") if op == "fusion" else None
        sliced = self._sliced_params(callee) if callee else {}
        dus = self._dus_root(callee) if callee else None
        if dus is not None:
            # in-place cache update: write = update region; the updated
            # buffer param is aliased, not re-read.
            upd_bytes, alias_idx = dus
            total = float(upd_bytes)
            if alias_idx is not None:
                sliced = dict(sliced)
                sliced[alias_idx] = 0.0
        else:
            total = float(_type_bytes(ins.type_str))
        for i, opnd in enumerate(ins.operands()):
            if i in sliced:
                total += sliced[i]
                continue
            sh = comp.shape_of(opnd)
            if sh:
                total += _type_bytes(sh)
        return total

    def _dus_root(self, callee: str):
        """If the fusion's root is a dynamic-update-slice (possibly behind
        bitcasts), return (update_bytes, aliased_param_index)."""
        key = "__dus__" + callee
        if key in self._fusion_free:
            return self._fusion_free[key]
        result = None
        comp = self.comps.get(callee)
        if comp is not None and comp.order:
            root = comp.instrs[comp.order[-1]]
            seen = 0
            while root.opcode in ("bitcast", "copy") and seen < 4:
                ops = root.operands()
                if not ops or ops[0] not in comp.instrs:
                    break
                root = comp.instrs[ops[0]]
                seen += 1
            if root.opcode == "dynamic-update-slice":
                ops = root.operands()
                upd = comp.shape_of(ops[1]) if len(ops) > 1 else None
                alias_idx = None
                if ops and ops[0] in comp.instrs and \
                        comp.instrs[ops[0]].opcode == "parameter":
                    m = re.match(r"\s*(\d+)", comp.instrs[ops[0]].rest)
                    if m:
                        alias_idx = int(m.group(1))
                if upd:
                    result = (2.0 * _type_bytes(upd), alias_idx)
        self._fusion_free[key] = result
        return result

    def _sliced_params(self, callee: str) -> Dict[int, float]:
        """param index -> charged bytes, for fusion params consumed only
        through dynamic-slice inside the fusion body."""
        key = "__sliced__" + callee
        if key in self._fusion_free:   # reuse dict as generic cache
            return self._fusion_free[key]
        out: Dict[int, float] = {}
        comp = self.comps.get(callee)
        if comp is not None:
            pname_to_idx = {}
            for iname in comp.order:
                ins = comp.instrs[iname]
                if ins.opcode == "parameter":
                    m = re.match(r"\s*(\d+)", ins.rest)
                    if m:
                        pname_to_idx[iname] = int(m.group(1))
            for pname, idx in pname_to_idx.items():
                consumers = [comp.instrs[i] for i in comp.order
                             if pname in comp.instrs[i].operands()]
                if consumers and all(c.opcode == "dynamic-slice"
                                     for c in consumers):
                    out[idx] = sum(_type_bytes(c.type_str)
                                   for c in consumers)
        self._fusion_free[key] = out
        return out

    def _trip_count(self, cond_name: str) -> Optional[int]:
        """Max s32/s64 constant in the cond computation closure."""
        seen, stack, best = set(), [cond_name], None
        while stack:
            cname = stack.pop()
            if cname in seen or cname not in self.comps:
                continue
            seen.add(cname)
            comp = self.comps[cname]
            for iname in comp.order:
                ins = comp.instrs[iname]
                if ins.opcode == "constant" and \
                        ins.type_str.split("[")[0] in ("s32", "s64", "u32"):
                    m = re.search(r"constant\((-?\d+)\)", "constant(" +
                                  ins.rest)
                    if m:
                        v = int(m.group(1))
                        best = v if best is None else max(best, v)
                if ins.opcode == "fusion":
                    callee = ins.attr("calls")
                    if callee:
                        stack.append(callee)
        return best

    # -- recursive roll-up -------------------------------------------------

    def cost_of(self, comp_name: str) -> CostTotals:
        if comp_name in self._cache:
            return self._cache[comp_name]
        total = CostTotals()
        comp = self.comps.get(comp_name)
        if comp is None:
            return total
        self._cache[comp_name] = total   # breaks cycles defensively
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            if op == "while":
                body = ins.attr("body")
                cond = ins.attr("condition")
                trip = self._trip_count(cond) if cond else None
                if trip is None or trip <= 0:
                    trip = 1
                    total.unparsed_whiles += 1
                inner = CostTotals()
                if body:
                    inner.add(self.cost_of(body))
                if cond:
                    inner.add(self.cost_of(cond))
                total.add(inner, mult=trip)
                continue
            if op == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}"
                                      r"|true_computation=%?([\w\.\-]+)"
                                      r"|false_computation=%?([\w\.\-]+))",
                                      ins.rest)
                names: List[str] = []
                for a, b, c in branches:
                    if a:
                        names += [x.strip().lstrip("%")
                                  for x in a.split(",")]
                    names += [x for x in (b, c) if x]
                if names:
                    worst = max((self.cost_of(n) for n in names),
                                key=lambda t: t.flops + t.bytes)
                    total.add(worst)
                total.bytes += self._op_bytes(comp, ins)
                continue
            if op == "fusion":
                callee = ins.attr("calls")
                if callee:
                    # flops (dots can hide inside fusions) but NOT bytes —
                    # bytes are the fusion boundary below.
                    inner = self.cost_of(callee)
                    total.flops += inner.flops
                    total.collective_bytes += inner.collective_bytes
                    for k, v in inner.collective_counts.items():
                        total.collective_counts[k] += v
                if self.mode == "raw" or callee is None or \
                        not self._is_elementwise_only(callee):
                    total.bytes += self._op_bytes(comp, ins)
                continue
            if op in ("call", "async-start"):
                callee = ins.attr("to_apply") or ins.attr("calls")
                if callee:
                    total.add(self.cost_of(callee))
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, ins)
                total.bytes += self._op_bytes(comp, ins)
                continue
            if op == "convolution":
                total.flops += self._conv_flops(comp, ins)
                total.bytes += self._op_bytes(comp, ins)
                continue
            hit = False
            for cop in COLLECTIVE_OPS:
                if op == cop or op.startswith(cop + "-"):
                    if op.endswith("-done"):
                        hit = True
                        break
                    opbytes = 0.0
                    for o in ins.operands():
                        sh = comp.shape_of(o)
                        if sh:
                            opbytes += _type_bytes(sh)
                    if opbytes == 0.0:
                        opbytes = _type_bytes(ins.type_str)
                    total.collective_bytes += opbytes
                    total.collective_counts[cop] += 1
                    total.bytes += self._op_bytes(comp, ins)
                    hit = True
                    break
            if hit:
                continue
            if op in _SKIP_BYTES:
                continue
            if self.mode != "raw" and op in _ELEMENTWISE and \
                    op not in ("copy", "transpose", "concatenate", "pad"):
                continue  # standalone pointwise: fuses into a neighbour
            total.bytes += self._op_bytes(comp, ins)
        return total

    def totals(self) -> CostTotals:
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).totals()
