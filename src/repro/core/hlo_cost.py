"""Structural, trip-count-aware cost analysis over post-SPMD HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, so any scan-over-layers model (all of ours) under-reports FLOPs/bytes
by ~n_layers — useless for rooflines.  This module re-derives the roofline
inputs by *parsing* the HLO module into computations and typed instructions
(not by regexing lines in isolation): operand shapes are resolved by name,
tuple types are handled, and dot contraction dims come from the
instruction's ``lhs_contracting_dims`` attribute.  Costs then propagate
bottom-up through ``fusion`` / ``call`` / ``conditional`` / ``while``:

- flops: dot (2 * result_elems * contraction) and convolution ops, found in
  any computation including inside fusions, multiplied up through while-loop
  trip counts (XLA's ``known_trip_count`` backend_config when present, else
  the loop condition's comparison constant — JAX scans always count 0..N);
- bytes: XLA's bytes-accessed convention at *fusion boundaries*
  (sum of operand + result sizes for every materializing op), so
  register/VMEM reuse inside a fusion is not double-counted;
  ``dynamic-slice`` / ``dynamic-update-slice`` charge the slice size, not
  the full stacked operand;
- collective bytes: operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, also trip-multiplied,
  broken down per collective kind.

Every charge is also recorded per opcode in ``CostTotals.by_op`` so reports
can show *where* FLOPs/bytes come from instead of one opaque scalar.

The compiled module is the per-device program (shapes are shard shapes), so
totals are per-chip; callers multiply by chip count for the global figure.

Known approximations (documented, conservative):
- elementwise/transcendental flops ignored (matmul-dominated workloads);
- `conditional` branches take the max-cost branch;
- a while whose bound cannot be parsed contributes trip=1 (counted in
  ``unparsed_whiles`` so it is visible rather than silent).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u2": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


# ---------------------------------------------------------------------------
# Tokenizing — bracket- and quote-aware, because HLO types/attrs nest
# ---------------------------------------------------------------------------

def _split_top(s: str, sep: str = ",") -> List[str]:
    """Split on `sep` at zero (), [], {} nesting depth, skipping quotes.

    This is the fix for the classic regex-walker bug: ``f32[64,128]`` must
    not be split at the comma inside the brackets.
    """
    parts, cur, depth, quoted = [], [], 0, False
    for ch in s:
        if quoted:
            cur.append(ch)
            if ch == '"':
                quoted = False
            continue
        if ch == '"':
            quoted = True
            cur.append(ch)
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _match_paren(s: str, start: int) -> int:
    """Index one past the ')' matching s[start] == '(' (quote-aware);
    len(s) if unbalanced."""
    depth, i, quoted = 0, start, False
    while i < len(s):
        ch = s[i]
        if quoted:
            if ch == '"':
                quoted = False
        elif ch == '"':
            quoted = True
        elif ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i + 1
        i += 1
    return len(s)


# ---------------------------------------------------------------------------
# Typed shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def byte_size(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 0)


_LEAF_RE = re.compile(r"^([a-z][a-z0-9]*)\[([\d,]*)\]")


def parse_type(type_str: str) -> List[Shape]:
    """HLO type string -> flat list of array Shapes (tuples flattened;
    token/opaque elements dropped)."""
    ts = type_str.strip()
    if ts.startswith("("):
        end = ts.rfind(")")
        if end < 0:
            return []
        out: List[Shape] = []
        for part in _split_top(ts[1:end]):
            out.extend(parse_type(part))
        return out
    m = _LEAF_RE.match(ts)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return []
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return [Shape(m.group(1), dims)]


def _shapes_bytes(shapes: List[Shape]) -> int:
    return sum(s.byte_size for s in shapes)


# ---------------------------------------------------------------------------
# Instructions and computations
# ---------------------------------------------------------------------------

_NAME = r"[\w.\-]+"
_HEAD_RE = re.compile(rf"^\s*(ROOT\s+)?%?({_NAME})\s*=\s*")
_OPCODE_RE = re.compile(rf"^({_NAME})\(")
_HDR_RE = re.compile(rf"^(ENTRY\s+)?%?({_NAME})\s*\(")


@dataclasses.dataclass
class Instr:
    name: str
    shapes: List[Shape]            # result type, tuples flattened
    opcode: str
    operands: List[str]            # operand names (or raw literals)
    attrs: Dict[str, str]          # raw attr text by key
    is_root: bool = False

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.shapes)

    def attr_name(self, key: str) -> Optional[str]:
        v = self.attrs.get(key)
        return v.lstrip("%") if v else None

    def attr_ints(self, key: str) -> List[int]:
        v = self.attrs.get(key, "")
        m = re.search(r"\{([\d,]*)\}", v)
        return [int(x) for x in m.group(1).split(",") if x] if m else []


def parse_instr(line: str) -> Optional[Instr]:
    hm = _HEAD_RE.match(line)
    if not hm:
        return None
    is_root, name = bool(hm.group(1)), hm.group(2)
    rest = line[hm.end():]
    if rest.startswith("("):                    # tuple-typed result
        i = _match_paren(rest, 0)
        type_str, rest = rest[:i], rest[i:].lstrip()
    else:                                       # single type has no spaces
        type_str, _, rest = rest.partition(" ")
    om = _OPCODE_RE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    close = _match_paren(rest, om.end() - 1)
    operand_str = rest[om.end():close - 1]
    operands = []
    for part in _split_top(operand_str):
        nm = re.search(rf"%({_NAME})$", part)
        operands.append(nm.group(1) if nm else part)
    attrs: Dict[str, str] = {}
    for part in _split_top(rest[close:].lstrip().lstrip(",")):
        k, eq, v = part.partition("=")
        if eq:
            attrs[k.strip()] = v.strip()
    return Instr(name, parse_type(type_str), opcode, operands, attrs, is_root)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]
    params: Dict[str, List[Shape]]   # header name -> type
    is_entry: bool = False

    @property
    def root(self) -> Optional[Instr]:
        for iname in self.order:
            if self.instrs[iname].is_root:
                return self.instrs[iname]
        return self.instrs[self.order[-1]] if self.order else None

    def shapes_of(self, operand: str) -> Optional[List[Shape]]:
        if operand in self.instrs:
            return self.instrs[operand].shapes
        return self.params.get(operand)

    def param_index(self, instr: Instr) -> Optional[int]:
        """Parameter number of a `parameter(N)` instruction."""
        if instr.opcode != "parameter" or not instr.operands:
            return None
        try:
            return int(instr.operands[0])
        except ValueError:
            return None


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, Computation]
    entry: Optional[str]


def parse_hlo(text: str) -> HloModule:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if not stripped.endswith("{") or "->" not in stripped or \
                    stripped.startswith("HloModule"):
                continue
            hm = _HDR_RE.match(stripped)
            if not hm:
                continue
            close = _match_paren(stripped, hm.end() - 1)
            params: Dict[str, List[Shape]] = {}
            for part in _split_top(stripped[hm.end():close - 1]):
                pname, colon, ptype = part.partition(":")
                if colon:
                    params[pname.strip().lstrip("%")] = parse_type(ptype)
            cur = Computation(hm.group(2), {}, [], params,
                              is_entry=bool(hm.group(1)))
            if cur.is_entry:
                entry = cur.name
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        ins = parse_instr(line)
        if ins is not None:
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    if cur is not None:
        comps[cur.name] = cur
    if entry is None and comps:          # fall back: last computation
        entry = list(comps)[-1]
    return HloModule(comps, entry)


# ---------------------------------------------------------------------------
# Cost totals with a per-op breakdown
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpCost:
    flops: float = 0.0
    bytes: float = 0.0
    count: float = 0.0


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    by_op: Dict[str, OpCost] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVE_OPS})
    collective_bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in COLLECTIVE_OPS})
    unparsed_whiles: int = 0

    def charge(self, op: str, *, flops: float = 0.0, bytes: float = 0.0,
               count: float = 1.0) -> None:
        self.flops += flops
        self.bytes += bytes
        oc = self.by_op.setdefault(op, OpCost())
        oc.flops += flops
        oc.bytes += bytes
        oc.count += count

    def charge_collective(self, op: str, ici_bytes: float) -> None:
        self.collective_bytes += ici_bytes
        self.collective_counts[op] += 1
        self.collective_bytes_by_op[op] += ici_bytes

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, oc in other.by_op.items():
            mine = self.by_op.setdefault(k, OpCost())
            mine.flops += oc.flops * mult
            mine.bytes += oc.bytes * mult
            mine.count += oc.count * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult
        for k, v in other.collective_bytes_by_op.items():
            self.collective_bytes_by_op[k] += v * mult
        self.unparsed_whiles += other.unparsed_whiles

    def breakdown(self, limit: Optional[int] = None
                  ) -> List[Tuple[str, OpCost]]:
        """(opcode, OpCost) rows, heaviest (flops, then bytes) first."""
        rows = sorted(self.by_op.items(),
                      key=lambda kv: (kv[1].flops, kv[1].bytes),
                      reverse=True)
        return rows[:limit] if limit else rows

    def by_op_dict(self) -> Dict[str, Dict[str, float]]:
        """JSON-friendly view of the per-op breakdown."""
        return {k: {"flops": oc.flops, "bytes": oc.bytes, "count": oc.count}
                for k, oc in self.by_op.items()}


# ---------------------------------------------------------------------------
# The cost model
# ---------------------------------------------------------------------------

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "custom-call",
    "opt-barrier", "optimization-barrier",
}

# Ops that are pure element-wise dataflow: on TPU these fuse into the
# producing/consuming matmul or reduction kernel, so in the tpu-fused byte
# model a fusion containing ONLY these contributes no extra HBM traffic
# (its bytes are already counted at the neighbouring matmul/reduce/copy).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "sign",
    "maximum", "minimum", "compare", "select", "and", "or", "xor", "not",
    "convert", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "logistic", "rsqrt", "sqrt", "cbrt", "power", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "is-finite",
    "broadcast", "reshape", "bitcast", "copy", "transpose", "iota",
    "constant", "parameter", "tuple", "get-tuple-element", "slice", "pad",
    "concatenate", "reverse", "erf", "atan2", "expm1", "log1p", "real",
    "imag", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "stochastic-convert", "reduce-precision",
    "bitcast-convert", "popcnt", "clz", "map",
}

# Standalone pointwise ops that still move HBM bytes even in the fused model
# (they materialize a layout change or a real copy).
_MATERIALIZING_POINTWISE = ("copy", "transpose", "concatenate", "pad")

_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _collective_kind(opcode: str) -> Optional[str]:
    for cop in COLLECTIVE_OPS:
        if opcode == cop or opcode.startswith(cop + "-"):
            return cop
    return None


class HloCostModel:
    """mode="tpu-fused" (default): bytes use the TPU fusion model — pure
    element-wise fusions are free (they fuse into neighbours on TPU), while
    matmuls, reductions, (dynamic-)slices/updates, gathers/scatters, copies
    and collectives pay operand+result traffic.  mode="raw": every CPU
    fusion boundary pays (XLA bytes-accessed convention on this backend) —
    reported alongside for transparency."""

    def __init__(self, text: str, mode: str = "tpu-fused"):
        self.module = parse_hlo(text)
        self.comps = self.module.computations
        self.mode = mode
        self._cache: Dict[str, CostTotals] = {}
        self._memo: Dict[str, object] = {}
        self.entry = self.module.entry

    # -- fusion body classification ---------------------------------------

    def _is_elementwise_only(self, comp_name: str) -> bool:
        """True if the computation (and its callees) contain only
        element-wise dataflow ops."""
        key = "ew:" + comp_name
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        ok = True
        if comp is not None:
            for iname in comp.order:
                ins = comp.instrs[iname]
                if ins.opcode == "fusion":
                    callee = ins.attr_name("calls")
                    if callee and not self._is_elementwise_only(callee):
                        ok = False
                        break
                    continue
                if ins.opcode not in _ELEMENTWISE:
                    ok = False
                    break
        self._memo[key] = ok
        return ok

    # -- per-op costs -----------------------------------------------------

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        if not ins.shapes:
            return 0.0
        out_elems = ins.shapes[0].elems
        contract = 1
        lhs = comp.shapes_of(ins.operands[0]) if ins.operands else None
        if lhs:
            dims = lhs[0].dims
            for c in ins.attr_ints("lhs_contracting_dims"):
                if c < len(dims):
                    contract *= dims[c]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: Computation, ins: Instr) -> float:
        if not ins.shapes or len(ins.operands) < 2:
            return 0.0
        out_elems = ins.shapes[0].elems
        rhs = comp.shapes_of(ins.operands[1])
        if not rhs or not rhs[0].dims:
            return 0.0
        kdims = rhs[0].dims
        # output-feature dim from dim_labels (e.g. b01f_01io->b01f), else
        # assume the last kernel dim.
        o_idx = len(kdims) - 1
        dl = ins.attrs.get("dim_labels", "")
        if "_" in dl:
            rhs_labels = dl.split("_")[1].split("->")[0]
            if "o" in rhs_labels and len(rhs_labels) == len(kdims):
                o_idx = rhs_labels.index("o")
        kernel_elems = rhs[0].elems
        return 2.0 * out_elems * (kernel_elems / max(1, kdims[o_idx]))

    def _operand_bytes(self, comp: Computation, name: str) -> int:
        shapes = comp.shapes_of(name)
        return _shapes_bytes(shapes) if shapes else 0

    def _op_bytes(self, comp: Computation, ins: Instr) -> float:
        """Operand+result bytes with slice-aware charging.

        A dynamic-slice reads only its result-sized window, not the whole
        operand (critical: scan-saved activation stacks (L, B, S, D) and
        stacked layer weights are consumed one layer-slice per iteration).
        Likewise dynamic-update-slice writes only the update region
        (in-place KV-cache updates).  Fusion operands consumed exclusively
        via dynamic-slice inside the fusion are charged at slice size.
        """
        op = ins.opcode
        if op in ("dynamic-slice", "gather"):
            return 2.0 * ins.result_bytes
        if op == "dynamic-update-slice":
            upd = (comp.shapes_of(ins.operands[1])
                   if len(ins.operands) > 1 else None)
            return 2.0 * _shapes_bytes(upd) if upd else \
                float(ins.result_bytes)
        if op == "scatter":
            upd = (comp.shapes_of(ins.operands[2])
                   if len(ins.operands) > 2 else None)
            return 2.0 * _shapes_bytes(upd) if upd else \
                float(ins.result_bytes)
        callee = ins.attr_name("calls") if op == "fusion" else None
        sliced = self._sliced_params(callee) if callee else {}
        dus = self._dus_root(callee) if callee else None
        if dus is not None:
            # in-place cache update: write = update region; the updated
            # buffer param is aliased, not re-read.
            upd_bytes, alias_idx = dus
            total = float(upd_bytes)
            if alias_idx is not None:
                sliced = dict(sliced)
                sliced[alias_idx] = 0.0
        else:
            total = float(ins.result_bytes)
        for i, opnd in enumerate(ins.operands):
            if i in sliced:
                total += sliced[i]
            else:
                total += self._operand_bytes(comp, opnd)
        return total

    def _dus_root(self, callee: str):
        """If the fusion's root is a dynamic-update-slice (possibly behind
        bitcasts/copies), return (update_bytes, aliased_param_index)."""
        key = "dus:" + callee
        if key in self._memo:
            return self._memo[key]
        result = None
        comp = self.comps.get(callee)
        root = comp.root if comp is not None else None
        if root is not None:
            hops = 0
            while root.opcode in ("bitcast", "copy") and hops < 4:
                nxt = comp.instrs.get(root.operands[0]) if root.operands \
                    else None
                if nxt is None:
                    break
                root, hops = nxt, hops + 1
            if root.opcode == "dynamic-update-slice":
                upd = (comp.shapes_of(root.operands[1])
                       if len(root.operands) > 1 else None)
                alias_idx = None
                target = comp.instrs.get(root.operands[0]) \
                    if root.operands else None
                if target is not None:
                    alias_idx = comp.param_index(target)
                if upd:
                    result = (2.0 * _shapes_bytes(upd), alias_idx)
        self._memo[key] = result
        return result

    def _sliced_params(self, callee: str) -> Dict[int, float]:
        """param index -> charged bytes, for fusion params consumed only
        through dynamic-slice inside the fusion body."""
        key = "sliced:" + callee
        if key in self._memo:
            return self._memo[key]
        out: Dict[int, float] = {}
        comp = self.comps.get(callee)
        if comp is not None:
            for iname in comp.order:
                ins = comp.instrs[iname]
                idx = comp.param_index(ins)
                if idx is None:
                    continue
                consumers = [comp.instrs[i] for i in comp.order
                             if iname in comp.instrs[i].operands]
                if consumers and all(c.opcode == "dynamic-slice"
                                     for c in consumers):
                    out[idx] = sum(float(c.result_bytes) for c in consumers)
        self._memo[key] = out
        return out

    # -- while trip counts -------------------------------------------------

    def _trip_count(self, ins: Instr) -> Optional[int]:
        """Trip count of a `while`: XLA's known_trip_count backend_config
        when present, else the loop condition's root comparison constant,
        else the max integer constant in the cond closure (conservative)."""
        bc = ins.attrs.get("backend_config", "")
        m = _TRIP_RE.search(bc)
        if m:
            return int(m.group(1))
        cond_name = ins.attr_name("condition")
        if not cond_name:
            return None
        trip = self._cond_compare_bound(cond_name)
        if trip is not None:
            return trip
        return self._max_int_constant(cond_name)

    def _cond_compare_bound(self, cond_name: str) -> Optional[int]:
        """Parse `compare(iv, N), direction=LT` style loop conditions.
        JAX scans count 0..N, so LT(iv, N) -> N trips, LE -> N+1."""
        comp = self.comps.get(cond_name)
        root = comp.root if comp is not None else None
        if root is None or root.opcode != "compare" or \
                len(root.operands) < 2:
            return None

        def const_val(name: str) -> Optional[int]:
            target = comp.instrs.get(name)
            if target is None or target.opcode != "constant" or \
                    not target.operands:
                return None
            try:
                return int(target.operands[0])
            except ValueError:
                return None

        direction = root.attrs.get("direction", "")
        lhs, rhs = const_val(root.operands[0]), const_val(root.operands[1])
        if direction == "LT" and rhs is not None:
            return rhs
        if direction == "LE" and rhs is not None:
            return rhs + 1
        if direction == "GT" and lhs is not None:
            return lhs
        if direction == "GE" and lhs is not None:
            return lhs + 1
        return None

    def _max_int_constant(self, cond_name: str) -> Optional[int]:
        """Max s32/u32/s64 constant in the cond computation closure."""
        seen, stack, best = set(), [cond_name], None
        while stack:
            cname = stack.pop()
            if cname in seen or cname not in self.comps:
                continue
            seen.add(cname)
            comp = self.comps[cname]
            for iname in comp.order:
                ins = comp.instrs[iname]
                if ins.opcode == "constant" and ins.shapes and \
                        ins.shapes[0].dtype in ("s32", "s64", "u32") and \
                        ins.operands:
                    try:
                        v = int(ins.operands[0])
                    except ValueError:
                        continue
                    best = v if best is None else max(best, v)
                elif ins.opcode == "fusion":
                    callee = ins.attr_name("calls")
                    if callee:
                        stack.append(callee)
        return best

    # -- recursive roll-up -------------------------------------------------

    def cost_of(self, comp_name: str) -> CostTotals:
        if comp_name in self._cache:
            return self._cache[comp_name]
        total = CostTotals()
        comp = self.comps.get(comp_name)
        if comp is None:
            return total
        self._cache[comp_name] = total   # breaks cycles defensively
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            if op == "while":
                trip = self._trip_count(ins)
                if trip is None or trip <= 0:
                    trip = 1
                    total.unparsed_whiles += 1
                inner = CostTotals()
                body = ins.attr_name("body")
                cond = ins.attr_name("condition")
                if body:
                    inner.add(self.cost_of(body))
                if cond:
                    inner.add(self.cost_of(cond))
                total.add(inner, mult=trip)
                continue
            if op == "conditional":
                names = []
                bc = ins.attrs.get("branch_computations", "")
                m = re.search(r"\{([^}]*)\}", bc)
                if m:
                    names += [x.strip().lstrip("%")
                              for x in m.group(1).split(",") if x.strip()]
                for key in ("true_computation", "false_computation"):
                    v = ins.attr_name(key)
                    if v:
                        names.append(v)
                if names:
                    worst = max((self.cost_of(n) for n in names),
                                key=lambda t: t.flops + t.bytes)
                    total.add(worst)
                total.charge(op, bytes=self._op_bytes(comp, ins))
                continue
            if op == "fusion":
                callee = ins.attr_name("calls")
                if callee:
                    # flops + collectives can hide inside fusions, but NOT
                    # bytes — bytes are the fusion boundary below.
                    inner = self.cost_of(callee)
                    total.flops += inner.flops
                    total.collective_bytes += inner.collective_bytes
                    for k, oc in inner.by_op.items():
                        if oc.flops:
                            mine = total.by_op.setdefault(k, OpCost())
                            mine.flops += oc.flops
                            mine.count += oc.count
                    for k, v in inner.collective_counts.items():
                        total.collective_counts[k] += v
                    for k, v in inner.collective_bytes_by_op.items():
                        total.collective_bytes_by_op[k] += v
                if self.mode == "raw" or callee is None or \
                        not self._is_elementwise_only(callee):
                    total.charge(op, bytes=self._op_bytes(comp, ins))
                continue
            if op in ("call", "async-start"):
                callee = ins.attr_name("to_apply") or ins.attr_name("calls")
                if callee:
                    total.add(self.cost_of(callee))
                continue
            if op == "dot":
                total.charge(op, flops=self._dot_flops(comp, ins),
                             bytes=self._op_bytes(comp, ins))
                continue
            if op == "convolution":
                total.charge(op, flops=self._conv_flops(comp, ins),
                             bytes=self._op_bytes(comp, ins))
                continue
            kind = _collective_kind(op)
            if kind is not None:
                if op.endswith("-done"):
                    continue             # counted at -start
                ici = sum(self._operand_bytes(comp, o)
                          for o in ins.operands)
                if ici == 0:
                    ici = float(ins.result_bytes)
                total.charge_collective(kind, ici)
                total.charge(kind, bytes=self._op_bytes(comp, ins))
                continue
            if op in _SKIP_BYTES:
                continue
            if self.mode != "raw" and op in _ELEMENTWISE and \
                    op not in _MATERIALIZING_POINTWISE:
                continue  # standalone pointwise: fuses into a neighbour
            total.charge(op, bytes=self._op_bytes(comp, ins))
        return total

    def totals(self) -> CostTotals:
        return self.cost_of(self.entry) if self.entry else CostTotals()


def analyze(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).totals()
