"""Analytical performance model of the TPU v1 — Section 7 of the paper.

The paper built a cycle model of the TPU ("Like an FPU, the TPU coprocessor
has a relatively easy microarchitecture to evaluate") that matched hardware
performance counters within 8% on average (Table 7), then used it to sweep
memory bandwidth / clock / matrix-unit size (Figure 11) and to evaluate the
hypothetical TPU' with GDDR5 weight memory.

This module rebuilds that model from the microarchitectural facts in the
paper and uses it for the same three purposes:

1. reproduce the Table 3 cycle-breakdown / TeraOps rows per app,
2. reproduce the Figure 11 sensitivity sweep and the TPU' result,
3. provide the service-time model consumed by `core.batching` (Table 4).

Microarchitectural facts encoded (all quoted from the paper):
- 256x256 8-bit MACs @ 700 MHz -> 92 TOPS peak (2 ops per MAC).
- Weight tiles are dim^2 bytes (64 KiB at 8 bit); shifting a tile into the
  array takes `dim` (=256) cycles; the Weight FIFO is 4 tiles deep and
  double-buffers fetches against compute.
- Weight Memory: 8 GiB DDR3 @ 34 GB/s  ->  34e9/700e6 = 48.6 B/cycle, so one
  tile fetch is 65536/48.6 = ~1350 cycles: exactly the paper's roofline ridge
  ("operations per byte need to reach peak performance is ~1350").
- 4096 256-wide 32-bit accumulators = 2048 usable rows double-buffered
  ("we picked 4096 by ... ~1350, rounded up to 2048 and then duplicated").
- Matrix op streams B rows through a resident tile in B pipelined cycles.
- 8w x 16a or 16w x 8a run at half speed; 16x16 at quarter (quant.bits_speed_factor).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

from repro.core.quant import bits_speed_factor


# ---------------------------------------------------------------------------
# Hardware description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPUHW:
    """Parametric TPU v1-like design point."""
    matrix_dim: int = 256
    clock_hz: float = 700e6
    mem_bw: float = 34e9            # weight-memory bytes/s
    n_accumulators: int = 4096      # matrix_dim-wide 32-bit accumulator rows
    fifo_tiles: int = 4
    w_bits: int = 8
    a_bits: int = 8

    @property
    def peak_ops(self) -> float:
        """Peak ops/s (MAC = 2 ops), derated for wide operands."""
        return (2.0 * self.matrix_dim ** 2 * self.clock_hz
                * bits_speed_factor(self.w_bits, self.a_bits))

    @property
    def bytes_per_cycle(self) -> float:
        return self.mem_bw / self.clock_hz

    @property
    def tile_bytes(self) -> int:
        return self.matrix_dim ** 2 * self.w_bits // 8

    @property
    def tile_fetch_cycles(self) -> float:
        return self.tile_bytes / self.bytes_per_cycle

    @property
    def ridge_ops_per_byte(self) -> float:
        """Roofline ridge point in ops-per-weight-byte (paper: ~1350 in MAC
        units; we report MACs/byte to match Fig. 5's x-axis)."""
        return self.peak_ops / 2.0 / self.mem_bw

    def scaled(self, *, memory: float = 1.0, clock: float = 1.0,
               matrix: float = 1.0, accumulators: float = 1.0) -> "TPUHW":
        return dataclasses.replace(
            self,
            mem_bw=self.mem_bw * memory,
            clock_hz=self.clock_hz * clock,
            matrix_dim=int(round(self.matrix_dim * matrix)),
            n_accumulators=int(round(self.n_accumulators * accumulators)),
        )


TPU_V1 = TPUHW()
# TPU': "Designing an interface circuit for GDDR5 memory, as in the K80,
# would improve Weight Memory bandwidth by more than a factor of five,
# shifting its roofline ridge point from 1350 to 250."  34 * 1350/250 = 183.6.
TPU_PRIME = TPU_V1.scaled(memory=1350.0 / 250.0)


# ---------------------------------------------------------------------------
# Workload description (Table 1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                # "fc" | "conv" | "vector"
    d_in: int = 0
    d_out: int = 0
    count: int = 1           # identical layers collapsed
    reuse: float = 1.0       # spatial weight reuse (conv output positions)
    mac_utilization: float = 1.0  # shallow-feature-depth derating (CNN1)


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """One of the six production NNs; dims chosen to match Table 1's weight
    counts and ops/weight-byte, plus details quoted in the text (600x600
    LSTM1 matrices, CNN1's four FC layers at intensity 32, ...)."""
    name: str
    layers: Tuple[LayerSpec, ...]
    batch: int
    nonmatrix_frac: float    # Table 3 row 6
    share: float             # deployment share (Table 1 last column)
    paper_tops: float        # Table 3 row 9 (validation target)
    raw_frac: float = 0.0    # Table 3 row 7, serialized when raw_serial
    raw_serial: bool = False  # matrix unit idles on RAW deps (LSTM1/CNN1 text)
    sync_cycles_per_layer: float = 0.0  # "delay slot" sync exposure (§2)

    @property
    def weight_bytes(self) -> int:
        return sum(l.d_in * l.d_out * l.count for l in self.layers
                   if l.kind != "vector")

    @property
    def macs_per_batch(self) -> float:
        return sum(l.d_in * l.d_out * l.count * self.batch * l.reuse
                   for l in self.layers if l.kind != "vector")

    @property
    def ops_per_weight_byte(self) -> float:
        """The paper's operational intensity (MACs per weight byte)."""
        return self.macs_per_batch / self.weight_bytes


def _fc(d_in, d_out, count=1, **kw):
    return LayerSpec("fc", d_in, d_out, count, **kw)


def _conv(d_in, d_out, count=1, reuse=1.0, **kw):
    return LayerSpec("conv", d_in, d_out, count, reuse=reuse, **kw)


# Layer dims reverse-engineered to satisfy Table 1 (weights, ops/byte, batch)
# and the quoted structural details; nonmatrix_frac from Table 3 row 6.
PAPER_APPS: Tuple[AppSpec, ...] = (
    AppSpec("MLP0", (_fc(2000, 2000, 5),), batch=200,
            nonmatrix_frac=0.175, share=0.305, paper_tops=12.3),
    AppSpec("MLP1", (_fc(1118, 1118, 4),), batch=168,
            nonmatrix_frac=0.319, share=0.305, paper_tops=9.7),
    AppSpec("LSTM0", (_fc(1472, 1472, 24),), batch=64,
            nonmatrix_frac=0.179, share=0.145, paper_tops=3.7),
    # LSTM1: "Consider the 600x600 matrix used in LSTM1" — 37 FC layers of
    # 600x1536 give the 34M weights of Table 1 with heavy tile fragmentation.
    # Cross-timestep RAW dependences expose per-layer "delay slots" (§2); the
    # sync exposure is calibrated to the Table 3 counters, as the paper's own
    # model was calibrated against hardware counters.
    AppSpec("LSTM1", (_fc(600, 1536, 37),), batch=96,
            nonmatrix_frac=0.103, share=0.145, paper_tops=2.8,
            raw_frac=0.106, raw_serial=True, sync_cycles_per_layer=10800),
    AppSpec("CNN0", (_conv(707, 707, 16, reuse=361.0),), batch=8,
            nonmatrix_frac=0.218, share=0.025, paper_tops=86.0),
    # CNN1: 72 conv layers (~30M weights, "some layers have shallow feature
    # depths" -> half the MACs useful) + 4 FC layers (~70M weights) that "run
    # at an operational intensity of just 32"; "23% of cycles have stalls for
    # RAW dependences in the pipeline" -> serialized.
    AppSpec("CNN1", (_conv(646, 646, 72, reuse=180.0, mac_utilization=0.487),
                     _fc(2958, 5916, 4)), batch=32,
            nonmatrix_frac=0.187, share=0.025, paper_tops=14.1,
            raw_frac=0.228, raw_serial=True),
)

APP_BY_NAME: Dict[str, AppSpec] = {a.name: a for a in PAPER_APPS}


# ---------------------------------------------------------------------------
# Cycle model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PerfResult:
    app: str
    total_cycles: float
    active_cycles: float
    stall_cycles: float
    shift_cycles: float
    nonmatrix_cycles: float
    useful_macs: float
    time_s: float
    tops: float                  # 2*useful_macs / time, in 1e12 ops/s
    ips: float                   # inferences (batch items) per second

    @property
    def active_frac(self):
        return self.active_cycles / self.total_cycles

    @property
    def stall_frac(self):
        return self.stall_cycles / self.total_cycles

    @property
    def shift_frac(self):
        return self.shift_cycles / self.total_cycles

    @property
    def nonmatrix_frac(self):
        return self.nonmatrix_cycles / self.total_cycles


# Fraction of non-matrix work hidden by overlapped execution ("Computation is
# often done one layer at a time, with overlapped execution allowing the
# matrix multiply unit to hide most non-critical-path operations", §2).
NONMATRIX_OVERLAP = 0.5


def _layer_cycles(layer: LayerSpec, batch: int, hw: TPUHW,
                  sync: float = 0.0):
    """Cycles for one matrix layer: (time, active, stall, shift, useful_macs).

    Tiling: ceil(d_in/dim) x ceil(d_out/dim) weight tiles.  The array streams
    `rows = batch*reuse` inputs per tile; the accumulators bound the rows in
    flight to n_acc/2 (double-buffered), so longer streams split into chunks.
    The Read_Weights DMA is decoupled (access/execute, [Smi82]), so the layer
    runs in max(total fetch, total compute) — fetches stream ahead through
    the 4-deep Weight FIFO.  Multi-chunk layers whose tile working set
    exceeds the FIFO must re-fetch weight tiles once per chunk.
    Shifting a tile into the array costs `dim` cycles, exposed only when the
    stream is too short to hide it.
    """
    dim = hw.matrix_dim
    speed = bits_speed_factor(hw.w_bits, hw.a_bits)
    row_tiles = math.ceil(layer.d_in / dim)
    col_tiles = math.ceil(layer.d_out / dim)
    tiles = row_tiles * col_tiles
    rows_total = batch * layer.reuse
    chunk_cap = max(1, hw.n_accumulators // 2)
    n_chunks = max(1, math.ceil(rows_total / chunk_cap))
    refetch = n_chunks if (n_chunks > 1 and tiles > hw.fifo_tiles) else 1

    fetch_total = tiles * refetch * hw.tile_fetch_cycles
    compute_total = tiles * rows_total / speed      # wide operands derate
    # Shift exposure: per (tile, chunk), dim cycles hidden under the larger
    # of compute-per-tile and fetch-per-tile; exposed for short streams.
    per_tile_compute = (rows_total / n_chunks) / speed
    shift_exposed = tiles * refetch * max(
        0.0, min(dim, hw.tile_fetch_cycles) - per_tile_compute)
    shift_exposed = min(shift_exposed, tiles * refetch * dim)

    time = max(fetch_total, compute_total + shift_exposed) + sync
    active = compute_total
    shift = min(tiles * refetch * dim, max(0.0, time - active))
    stall = max(0.0, time - active - shift)
    useful = rows_total * layer.d_in * layer.d_out * layer.mac_utilization
    c = layer.count
    return time * c, active * c, stall * c, shift * c, useful * c


def simulate(app: AppSpec, hw: TPUHW = TPU_V1) -> PerfResult:
    matrix_time = active = stall = shift = useful = 0.0
    for layer in app.layers:
        if layer.kind == "vector":
            continue
        t, a, st, sh, u = _layer_cycles(layer, app.batch, hw,
                                        sync=app.sync_cycles_per_layer)
        matrix_time += t
        active += a
        stall += st
        shift += sh
        useful += u
    # Serialized overheads: the un-overlappable half of non-matrix work, plus
    # RAW-dependence pipeline stalls for apps where the text reports the
    # matrix unit idling on them.
    serial_frac = (1.0 - NONMATRIX_OVERLAP) * app.nonmatrix_frac
    if app.raw_serial:
        serial_frac += app.raw_frac
    total = matrix_time / max(1e-9, 1.0 - serial_frac)
    nonmatrix = total - matrix_time
    time_s = total / hw.clock_hz
    tops = 2.0 * useful / time_s / 1e12
    ips = app.batch / time_s
    return PerfResult(app.name, total, active, stall, shift, nonmatrix,
                      useful, time_s, tops, ips)


def service_time(app: AppSpec, hw: TPUHW = TPU_V1, batch=None) -> float:
    """Seconds to run one batch of `batch` items (for core.batching)."""
    if batch is None:
        return simulate(app, hw).time_s
    return simulate(dataclasses.replace(app, batch=batch), hw).time_s


# ---------------------------------------------------------------------------
# Roofline (Figure 5) and sensitivity (Figure 11)
# ---------------------------------------------------------------------------

def roofline_point(app: AppSpec, hw: TPUHW = TPU_V1):
    """(intensity MACs/weight-byte, attainable TOPS, achieved TOPS)."""
    intensity = app.ops_per_weight_byte
    attain = min(hw.peak_ops, 2.0 * intensity * hw.mem_bw) / 1e12
    achieved = simulate(app, hw).tops
    return intensity, attain, achieved


def weighted_mean_perf(hw: TPUHW, baseline: TPUHW = TPU_V1,
                       weighted: bool = True) -> float:
    """Mean relative performance vs baseline over the six apps (Fig. 11)."""
    rels = []
    ws = []
    for app in PAPER_APPS:
        rels.append(simulate(app, hw).tops / simulate(app, baseline).tops)
        ws.append(app.share if weighted else 1.0)
    if weighted:
        return sum(r * w for r, w in zip(rels, ws)) / sum(ws)
    return math.exp(sum(math.log(r) for r in rels) / len(rels))


FIG11_KNOBS = ("memory", "clock+", "clock", "matrix+", "matrix")


def fig11_sweep(scales: Sequence[float] = (0.25, 0.5, 1.0, 2.0, 4.0),
                weighted: bool = True) -> Dict[str, List[Tuple[float, float]]]:
    """Weighted-mean relative perf as each knob scales 0.25x..4x."""
    out: Dict[str, List[Tuple[float, float]]] = {k: [] for k in FIG11_KNOBS}
    for s in scales:
        out["memory"].append((s, weighted_mean_perf(
            TPU_V1.scaled(memory=s), weighted=weighted)))
        out["clock"].append((s, weighted_mean_perf(
            TPU_V1.scaled(clock=s), weighted=weighted)))
        out["clock+"].append((s, weighted_mean_perf(
            TPU_V1.scaled(clock=s, accumulators=s), weighted=weighted)))
        out["matrix"].append((s, weighted_mean_perf(
            TPU_V1.scaled(matrix=s), weighted=weighted)))
        out["matrix+"].append((s, weighted_mean_perf(
            TPU_V1.scaled(matrix=s, accumulators=s * s), weighted=weighted)))
    return out


def tpu_prime_gains() -> Dict[str, float]:
    """The TPU' evaluation: GDDR5 memory, optional 1.05 GHz clock.

    Paper: GDDR5 alone -> GM 2.6 / WM 3.9; clock alone -> ~no change;
    both -> GM 2.9 but WM unchanged, 'so TPU' just has faster memory'.
    """
    gddr5 = TPU_V1.scaled(memory=1350.0 / 250.0)
    clock15 = TPU_V1.scaled(clock=1.5, accumulators=1.5)
    both = TPU_V1.scaled(memory=1350.0 / 250.0, clock=1.5, accumulators=1.5)
    return {
        "gddr5_gm": weighted_mean_perf(gddr5, weighted=False),
        "gddr5_wm": weighted_mean_perf(gddr5, weighted=True),
        "clock1.5_gm": weighted_mean_perf(clock15, weighted=False),
        "clock1.5_wm": weighted_mean_perf(clock15, weighted=True),
        "both_gm": weighted_mean_perf(both, weighted=False),
        "both_wm": weighted_mean_perf(both, weighted=True),
    }


# ---------------------------------------------------------------------------
# Unified Buffer occupancy (Table 8)
# ---------------------------------------------------------------------------

def unified_buffer_mib(app: AppSpec) -> float:
    """Modeled UB footprint: double-buffered input+output activations of the
    hungriest layer — rows in flight (bounded by the 2048-row accumulator
    stream) x (d_in + d_out) bytes, x2 for ping-pong."""
    mib = 0.0
    for l in app.layers:
        if l.kind == "vector":
            continue
        rows = min(2048, int(app.batch * l.reuse))
        mib = max(mib, 2.0 * rows * (l.d_in + l.d_out) / 2**20)
    return mib
