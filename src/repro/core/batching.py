"""Latency-aware batching — the paper's Table 4 discipline as a scheduler.

The paper's central serving observation: inference is 99th-percentile
response-time bound, and batch size is the lever that trades latency for
throughput.  CPUs/GPUs must drop to batch 16 to meet MLP0's 7 ms limit
(reaching only 42%/37% of their peak IPS) while the TPU still runs batch 200
(80% of peak).

This module provides:

- ``LatencyModel``: p99(B) = queue/host constant + per-batch service time,
  either calibrated from two measured points (paper platforms) or derived
  from `core.perfmodel` / measured step times (our serving runtime),
- ``choose_batch``: largest batch meeting a deadline — Table 4's policy,
- ``AdmissionPolicy``: the online form of that policy — given the clock,
  the pending deadlines and the next arrival, decide "launch a batch of B
  now" or "wait for more work".  This is the single decision procedure
  shared by BOTH serving backends: the virtual-time simulator below and
  the live continuous-batching engine (`repro.engine`), which is what lets
  a property test assert the two make identical admission decisions.
- ``BatchQueue``: a deterministic virtual-time request-batching simulator
  (one backend of the policy) used by the serving example and the property
  tests: requests accumulate until either (a) the batch that *would* form
  can no longer finish by the earliest request's deadline, or (b) the
  chosen max batch is reached.  Deterministic execution (static shapes, no
  speculation) is what makes the p99 predictable — the TPU argument,
  applied to the serving runtime.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
from collections.abc import Mapping as _MappingABC
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """p99 latency and throughput as a function of batch size.

    latency(B)  = fixed + per_item * B     (service + host + queue margin)
    ips(B)      = B / (service_fixed + service_per_item * B)
    """
    name: str
    fixed_s: float
    per_item_s: float
    service_fixed_s: float
    service_per_item_s: float

    def p99_latency(self, batch: int) -> float:
        return self.fixed_s + self.per_item_s * batch

    def service_time(self, batch: int) -> float:
        return self.service_fixed_s + self.service_per_item_s * batch

    def ips(self, batch: int) -> float:
        return batch / self.service_time(batch)

    @classmethod
    def from_two_points(cls, name: str,
                        p1: Tuple[int, float, float],
                        p2: Tuple[int, float, float]) -> "LatencyModel":
        """Calibrate from two (batch, p99_s, ips) measurements (Table 4)."""
        (b1, l1, i1), (b2, l2, i2) = p1, p2
        per_item = (l2 - l1) / (b2 - b1)
        fixed = l1 - per_item * b1
        s1, s2 = b1 / i1, b2 / i2
        sper = (s2 - s1) / (b2 - b1)
        sfix = s1 - sper * b1
        return cls(name, fixed, per_item, sfix, sper)


# Table 4, calibrated from the paper's two measured rows per platform.
TABLE4_CPU = LatencyModel.from_two_points(
    "Haswell", (16, 7.2e-3, 5482), (64, 21.3e-3, 13194))
TABLE4_GPU = LatencyModel.from_two_points(
    "K80", (16, 6.7e-3, 13461), (64, 8.3e-3, 36465))
TABLE4_TPU = LatencyModel.from_two_points(
    "TPU", (200, 7.0e-3, 225000), (250, 10.0e-3, 280000))


def choose_batch(model: LatencyModel, deadline_s: float,
                 max_batch: int = 4096) -> int:
    """Largest batch whose modeled p99 meets the deadline (0 if none)."""
    lo, hi, best = 1, max_batch, 0
    while lo <= hi:
        mid = (lo + hi) // 2
        if model.p99_latency(mid) <= deadline_s:
            best, lo = mid, mid + 1
        else:
            hi = mid - 1
    return best


def table4_row(model: LatencyModel, deadline_s: float = 7e-3,
               max_batch: int = 4096):
    """(chosen batch, p99, IPS at chosen batch, % of max IPS) — one Table 4
    comparison row.  Max IPS evaluated at the platform's saturating batch."""
    b = choose_batch(model, deadline_s, max_batch)
    ips = model.ips(b) if b else 0.0
    ips_max = model.ips(max_batch)
    return b, model.p99_latency(b) if b else float("inf"), ips, ips / ips_max


# ---------------------------------------------------------------------------
# Admission policy (shared by the simulator and the live engine)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    arrival_s: float
    deadline_s: float          # absolute
    rid: int = 0


# SLO classes, best first.  ``priority_rank`` is total order position:
# anything unknown sorts AFTER the known classes (conservative — an
# unrecognized class never outranks interactive traffic).
PRIORITY_CLASSES = ("interactive", "batch")


def priority_rank(cls: str) -> int:
    """Smaller is better; unknown classes rank last."""
    try:
        return PRIORITY_CLASSES.index(cls)
    except ValueError:
        return len(PRIORITY_CLASSES)


@dataclasses.dataclass(frozen=True)
class Admission:
    """One scheduler decision: launch ``batch`` requests now, or wait for
    more arrivals until ``wait_until``.  When the class-aware path ran
    (quota enforcement may skip over a quota-blocked request to admit a
    later one), ``picks`` carries the explicit pending-queue indices of
    the cohort; ``picks is None`` means the legacy prefix cohort
    ``pending[:batch]``."""
    launch: bool
    batch: int = 0
    wait_until: float = 0.0
    picks: Optional[Tuple[int, ...]] = None


class AdmissionPolicy:
    """The Table 4 trade, made online — extracted from the old BatchQueue
    inner loop so the virtual-time simulator and the live engine consume
    the *same* decision procedure.

    Given the clock and the sorted pending deadlines: form the largest
    batch B <= capacity such that now + service_time(B) meets the earliest
    pending deadline; launch immediately if waiting for one more request
    would break that bound, otherwise wait for the next arrival (at most
    ``max_wait_s`` away).

    ``class_quotas`` adds SLO-class admission (overload robustness):
    ``{"batch": k}`` caps the batch class at ``k`` concurrently active
    slots, so a flood of batch traffic can never occupy the slots an
    interactive arrival needs.  The pending queue is ordered class-first
    (see ``SlotScheduler.push``) and the cohort shrinks from its tail,
    so under pressure the lowest class is dropped first — shrink *by
    class before deadline*.  A class without a quota entry is uncapped.

    Quota keys generalize to tuples for multi-model multiplexing: a
    request classed as ``(model, cls)`` is metered against the quota
    entries for the full pair AND each component, so ``{"batch": 4}``
    still caps batch traffic across all models while ``{"moe-a": 2}``
    caps one model across all classes and ``{("moe-a", "batch"): 1}``
    pins the intersection.  String-classed requests behave exactly as
    before — the tuple path is additive.
    """

    def __init__(self, service_time: Callable[[int], float],
                 max_batch: int = 256, max_wait_s: float = 2e-3,
                 class_quotas: Optional[Mapping[Any, int]] = None):
        self.service_time = service_time
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.class_quotas = dict(class_quotas or {})

    def decide(self, now: float, deadlines: Sequence[float],
               next_arrival: Optional[float] = None,
               capacity: Optional[int] = None,
               costs: Optional[Sequence[int]] = None,
               budget: Union[int, Mapping[Optional[str], int], None] = None,
               classes: Optional[Sequence[Any]] = None,
               active_by_class: Optional[Mapping[Any, int]] = None
               ) -> Admission:
        """``deadlines``: absolute deadlines of pending requests, sorted
        ascending (an empty queue is a no-launch wait).  ``capacity``
        caps the batch below ``max_batch`` (the live engine passes its
        free-slot count).

        ``costs``/``budget`` add memory-aware admission (the paged KV
        engine): ``costs[i]`` is pending request i's worst-case resource
        claim (KV blocks not already shared) and ``budget`` what the pool
        has free — the batch shrinks until its summed cost fits, and an
        unaffordable head-of-line request waits (blocks drain at
        retirement, so waiting makes progress; "free slot exists" is no
        longer sufficient).

        ``classes``/``active_by_class`` switch on per-class slot quotas:
        ``classes[i]`` is pending request i's SLO class — a plain string
        or, for multi-model multiplexing, a ``(model, cls)`` tuple
        metered against the pair and both components — and
        ``active_by_class`` the slots each quota key already holds.  A
        request whose class quota is full is *skipped over* (not a
        barrier: later pending requests of an unblocked class still
        admit), so the cohort is returned as explicit ``picks`` indices
        rather than a prefix length.  When classes are tuples, ``budget``
        may be a per-model mapping ``{model: free}`` so one model's
        memory pressure sheds only that model's cohort tail instead of
        starving every model behind a shared number."""
        if not deadlines:
            return Admission(False, wait_until=(
                next_arrival if next_arrival is not None else now))
        cap = self.max_batch if capacity is None \
            else min(capacity, self.max_batch)
        if classes is not None:
            return self._decide_classes(now, deadlines, next_arrival, cap,
                                        costs, budget, classes,
                                        active_by_class)
        earliest = deadlines[0]
        b = min(len(deadlines), cap)
        # shrink until the batch finishes by the earliest deadline
        while b > 1 and now + self.service_time(b) > earliest:
            b -= 1
        if costs is not None and budget is not None:
            # memory-aware: shrink until the cohort's worst-case claim fits
            while b > 0 and sum(costs[:b]) > budget:
                b -= 1
            if b == 0:
                return Admission(False, wait_until=(
                    next_arrival if next_arrival is not None else now))
        # can we afford to wait for more work?
        can_wait = (
            b < cap and next_arrival is not None
            and next_arrival - now <= self.max_wait_s
            and next_arrival + self.service_time(
                min(b + 1, cap)) <= earliest)
        if can_wait:
            return Admission(False, wait_until=next_arrival)
        return Admission(True, batch=b)

    @staticmethod
    def _quota_keys(c) -> Tuple:
        """Quota keys a classed request is metered against: a string
        class meters only itself; a ``(model, cls)`` tuple meters the
        pair and each non-None component (deduplicated), so per-model
        and per-class quotas compose without cross-products in config."""
        if not isinstance(c, tuple):
            return (c,)
        keys = [c]
        for part in c:
            if part is not None and part not in keys:
                keys.append(part)
        return tuple(keys)

    def _decide_classes(self, now, deadlines, next_arrival, cap,
                        costs, budget, classes, active_by_class):
        """Class-aware cohort selection.  With no quotas configured and a
        uniform class this reduces exactly to the legacy prefix path
        (no request is ever skipped, so picks == range(b))."""
        used: Dict[Any, int] = dict(active_by_class or {})
        sel: List[int] = []
        for i, c in enumerate(classes):
            if len(sel) >= cap:
                break
            keys = self._quota_keys(c)
            if any(self.class_quotas.get(k) is not None
                   and used.get(k, 0) >= self.class_quotas[k]
                   for k in keys):
                continue                       # quota-blocked: skip, not stop
            sel.append(i)
            for k in keys:
                used[k] = used.get(k, 0) + 1
        wait = Admission(False, wait_until=(
            next_arrival if next_arrival is not None else now))
        if not sel:
            return wait
        # shrink from the TAIL — the queue is class-ordered, so pressure
        # sheds the lowest class first, then the latest deadline
        earliest = min(deadlines[i] for i in sel)
        while len(sel) > 1 and now + self.service_time(len(sel)) > earliest:
            sel.pop()
            earliest = min(deadlines[i] for i in sel)
        if costs is not None and budget is not None:
            if isinstance(budget, _MappingABC):
                # per-model budgets: each model sheds its OWN cohort
                # tail until its claim fits its pool — a starved model
                # skips, it never barriers the others
                def model_of(i):
                    c = classes[i]
                    return c[0] if isinstance(c, tuple) else None
                for m, free in budget.items():
                    mine = [i for i in sel if model_of(i) == m]
                    while mine and sum(costs[i] for i in mine) > free:
                        sel.remove(mine.pop())
            else:
                while sel and sum(costs[i] for i in sel) > budget:
                    sel.pop()
            if not sel:
                return wait
        can_wait = (
            len(sel) < cap and next_arrival is not None
            and next_arrival - now <= self.max_wait_s
            and next_arrival + self.service_time(
                min(len(sel) + 1, cap)) <= earliest)
        if can_wait:
            return Admission(False, wait_until=next_arrival)
        return Admission(True, batch=len(sel), picks=tuple(sel))


# ---------------------------------------------------------------------------
# Virtual-time batch queue (simulator backend of the admission policy)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchRecord:
    start_s: float
    finish_s: float
    rids: Tuple[int, ...]
    deadlines_met: bool


class BatchQueue:
    """Deterministic virtual-time batching simulator: one backend of
    :class:`AdmissionPolicy` (the live `repro.engine` is the other).  The
    engine-is-busy-until-finish semantics live here; the batch-vs-deadline
    decision lives in the policy.
    """

    def __init__(self, service_time: Callable[[int], float],
                 max_batch: int = 256, max_wait_s: float = 2e-3,
                 policy: Optional[AdmissionPolicy] = None):
        self.policy = policy or AdmissionPolicy(
            service_time, max_batch=max_batch, max_wait_s=max_wait_s)
        self.service_time = self.policy.service_time
        self.max_batch = self.policy.max_batch
        self.max_wait_s = self.policy.max_wait_s

    def run(self, requests: Sequence[Request]) -> List[BatchRecord]:
        pending: List[Request] = []
        records: List[BatchRecord] = []
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        i, now = 0, 0.0
        while i < len(reqs) or pending:
            # admit everything that has arrived by `now`
            while i < len(reqs) and reqs[i].arrival_s <= now:
                bisect.insort(pending, reqs[i],
                              key=lambda r: r.deadline_s)
                i += 1
            if not pending:
                now = reqs[i].arrival_s
                continue
            next_arrival = reqs[i].arrival_s if i < len(reqs) else None
            act = self.policy.decide(
                now, [r.deadline_s for r in pending], next_arrival)
            if not act.launch:
                now = act.wait_until
                continue
            batch = pending[:act.batch]
            del pending[:act.batch]
            finish = now + self.service_time(act.batch)
            records.append(BatchRecord(
                now, finish, tuple(r.rid for r in batch),
                all(finish <= r.deadline_s for r in batch)))
            now = finish
        return records


def p99(latencies: Sequence[float]) -> float:
    """Nearest-rank 99th percentile: the smallest value with at least 99%
    of the sample at or below it — the ``ceil(0.99 n)``-th order
    statistic.  The old ``int(0.99 * n)`` indexing had a nearest-rank
    off-by-one at multiples of 100: at n=100 it indexed the MAX,
    overstating the tail by a whole rank.  Integer arithmetic keeps the
    rank exact by construction, with no reasoning about float rounding
    required."""
    if not latencies:
        return 0.0
    xs = sorted(latencies)
    rank = -((-99 * len(xs)) // 100)          # ceil(0.99 n), exactly
    return xs[rank - 1]


def poisson_arrivals(rate_per_s: float, n: int, deadline_s: float,
                     seed: int = 0) -> List[Request]:
    """Deterministic pseudo-Poisson arrival process (no wall clock)."""
    import random
    rng = random.Random(seed)
    t, out = 0.0, []
    for rid in range(n):
        t += rng.expovariate(rate_per_s)
        out.append(Request(arrival_s=t, deadline_s=t + deadline_s, rid=rid))
    return out
