"""Symmetric integer quantization — the TPU paper's numerical contract.

The TPU v1 runs inference on 8-bit signed/unsigned integers with 32-bit
accumulators ("65,536 8-bit MAC ... 16-bit products are collected in the 4 MiB
of 32-bit Accumulators").  The paper's flow is: train in floating point, then a
*quantization* step maps weights (and activations) to narrow integers.

This module implements that flow for the JAX framework:

- symmetric per-tensor / per-channel int8 (and int4) quantization,
- activation calibration (absmax / percentile over a calibration batch),
- stochastic rounding (used by the gradient-compression path, not by the
  paper-faithful inference path),
- a `QTensor` pytree carrying int data + fp scales, consumed by
  `repro.kernels.ops.qmatmul` and `repro.core.qlinear`.

Mixed-precision note from the paper: 8w×8a runs at full speed, 8×16 at half,
16×16 at quarter speed.  `bits_speed_factor` encodes that for the perfmodel.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INT_DTYPES = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}


def int_bounds(bits: int, signed: bool = True) -> Tuple[int, int]:
    """Inclusive (min, max) representable values for a `bits`-wide integer."""
    if signed:
        return -(2 ** (bits - 1)) + 1, 2 ** (bits - 1) - 1  # symmetric: drop -128
    return 0, 2**bits - 1


@dataclasses.dataclass(frozen=True)
class QTensor:
    """Quantized tensor: int values + float scale(s).

    ``values``  int8/int4-in-int8 data, shape S.
    ``scale``   fp32 scale, broadcastable to S (per-tensor scalar or per-channel).
    ``bits``    nominal bit width (4 or 8; int4 is stored in int8 containers,
                matching how XLA:TPU packs narrow ints).
    Dequantization: ``values.astype(f32) * scale``.
    """

    values: jax.Array
    scale: jax.Array
    bits: int = 8

    @property
    def shape(self):
        return self.values.shape

    @property
    def dtype(self):
        return self.values.dtype

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return self.values.astype(dtype) * self.scale.astype(dtype)

    @property
    def nbytes_weights(self) -> int:
        """Bytes of weight-memory traffic to stream this tensor once —
        the denominator of the paper's operational-intensity metric."""
        return int(np.prod(self.shape)) * self.bits // 8 + self.scale.size * 4


def _qtensor_flatten_with_keys(q: QTensor):
    GK = jax.tree_util.GetAttrKey
    return (((GK("values"), q.values), (GK("scale"), q.scale)), (q.bits,))


def _qtensor_flatten(q: QTensor):
    return ((q.values, q.scale), (q.bits,))


def _qtensor_unflatten(aux, children):
    values, scale = children
    return QTensor(values=values, scale=scale, bits=aux[0])


jax.tree_util.register_pytree_with_keys(
    QTensor, _qtensor_flatten_with_keys, _qtensor_unflatten,
    _qtensor_flatten)


def _absmax(x: jax.Array, axis, keepdims=True) -> jax.Array:
    return jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)


def compute_scale(x: jax.Array, bits: int = 8, axis=None,
                  percentile: Optional[float] = None) -> jax.Array:
    """Symmetric scale so that max|x| (or a percentile of |x|) maps to qmax."""
    _, qmax = int_bounds(bits)
    if percentile is None:
        amax = _absmax(x, axis=axis, keepdims=axis is not None)
    else:
        amax = jnp.percentile(jnp.abs(x), percentile, axis=axis,
                              keepdims=axis is not None)
    amax = jnp.maximum(amax, 1e-8)  # avoid div-by-zero on dead channels
    return (amax / qmax).astype(jnp.float32)


@partial(jax.jit, static_argnames=("bits", "axis", "stochastic"))
def quantize(x: jax.Array, bits: int = 8, axis=None, *,
             scale: Optional[jax.Array] = None,
             stochastic: bool = False,
             key: Optional[jax.Array] = None) -> QTensor:
    """Quantize ``x`` symmetrically to ``bits`` ints.

    axis=None  → per-tensor scale (paper's matrix-unit weight tiles).
    axis=k     → per-channel scales along every axis *except* k reduced;
                 e.g. for a (in, out) weight use axis=0 to get per-out-column
                 scales (reduce over rows).  In practice callers pass the
                 reduction axes via ``axis`` as understood by jnp.max.
    stochastic → stochastic rounding (for gradient compression).
    """
    if scale is None:
        scale = compute_scale(x, bits=bits, axis=axis)
    qmin, qmax = int_bounds(bits)
    scaled = x / scale
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding needs a PRNG key")
        noise = jax.random.uniform(key, scaled.shape, dtype=scaled.dtype) - 0.5
        rounded = jnp.floor(scaled + 0.5 + noise)
    else:
        rounded = jnp.round(scaled)
    q = jnp.clip(rounded, qmin, qmax).astype(jnp.int8 if bits <= 8 else jnp.int16)
    return QTensor(values=q, scale=scale, bits=bits)


def dequantize(q: QTensor, dtype=jnp.float32) -> jax.Array:
    return q.dequantize(dtype)


def fake_quant(x: jax.Array, bits: int = 8, axis=None) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator (QAT hook)."""
    q = quantize(x, bits=bits, axis=axis)
    dq = q.dequantize(x.dtype)
    return x + jax.lax.stop_gradient(dq - x)


# ---------------------------------------------------------------------------
# Weight quantization for model params
# ---------------------------------------------------------------------------

def quantize_weight(w: jax.Array, bits: int = 8) -> QTensor:
    """Per-output-channel symmetric quantization of a linear weight.

    Convention: weights are (..., d_in, d_out); only the contraction axis
    (d_in, second-to-last) is reduced, so scales are per-(stack..., column):
    stacked per-layer weights (L, d_in, d_out) get (L, 1, d_out) scales and
    remain scannable.  This matches the TPU loading a 256x256 weight tile
    per matrix column bank.
    """
    return quantize(w, bits=bits, axis=(w.ndim - 2,))


def quantize_embedding(w: jax.Array, bits: int = 8) -> QTensor:
    """Per-row (per-vocab-entry) quantization for embedding tables: gathers
    dequantize row-wise, and the tied LM head folds scales per output."""
    axes = tuple(range(1, w.ndim))
    return quantize(w, bits=bits, axis=axes)


_QUANT_PATH_RE = None  # compiled lazily


def _default_quant_predicate(path_str: str, leaf) -> bool:
    """Quantize matmul weights only: paths ending '.w' (linear / expert /
    conv weights) or embedding 'table's.  Norm scales, biases, RG-LRU /
    SSM per-channel params, positional tables stay fp — faithful to the TPU
    keeping non-matrix state out of the 8-bit datapath."""
    import re
    global _QUANT_PATH_RE
    if _QUANT_PATH_RE is None:
        _QUANT_PATH_RE = re.compile(
            r"(\.w$|(^|\.)table$|experts.*w_(gate|up|down)$)")
    if not (hasattr(leaf, "ndim") and leaf.ndim >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating)):
        return False
    if "dec_pos" in path_str:
        return False
    return bool(_QUANT_PATH_RE.search(path_str))


def quantize_tree(params, bits: int = 8, min_size: int = 4096,
                  predicate=None):
    """Post-training quantization of a parameter pytree.

    Matmul weights (path allowlist, ≥ ``min_size`` elements) become
    QTensors — these are the weights the paper streams from Weight Memory.
    Everything else stays fp.  Embedding tables (path contains "table") use
    per-row scales.  ``predicate(path_str, leaf) -> bool`` overrides.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        path_str = ".".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in path)
        if predicate is not None:
            do_q = predicate(path_str, leaf)
        else:
            do_q = (_default_quant_predicate(path_str, leaf)
                    and leaf.size >= min_size)
        if do_q:
            is_table = "table" in path_str
            out.append(quantize_embedding(leaf, bits=bits) if is_table
                       else quantize_weight(leaf, bits=bits))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_weight_bytes(params) -> int:
    """Total weight-memory bytes of a (possibly quantized) param tree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(leaf, QTensor):
            total += leaf.nbytes_weights
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# Activation calibration (the paper's User-Space-driver compile step)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Calibrator:
    """Accumulates absmax statistics over calibration batches.

    The TPU user-space driver compiles a model the first time it is evaluated;
    activation scales are fixed at that point.  We reproduce that: run
    ``observe`` over a few batches, then ``scales()`` freezes per-site scales.
    """

    bits: int = 8
    percentile: Optional[float] = 99.9
    _stats: dict = dataclasses.field(default_factory=dict)

    def observe(self, name: str, x: jax.Array) -> None:
        amax = float(jnp.percentile(jnp.abs(x), self.percentile)
                     if self.percentile is not None else jnp.max(jnp.abs(x)))
        self._stats[name] = max(self._stats.get(name, 0.0), amax)

    def scales(self) -> dict:
        _, qmax = int_bounds(self.bits)
        return {k: max(v, 1e-8) / qmax for k, v in self._stats.items()}


def bits_speed_factor(w_bits: int, a_bits: int) -> float:
    """Paper §2: 8×8 full speed, 8×16 or 16×8 half, 16×16 quarter."""
    f = 1.0
    if w_bits > 8:
        f *= 0.5
    if a_bits > 8:
        f *= 0.5
    return f


# ---------------------------------------------------------------------------
# Gradient compression (beyond-paper: quantize the cross-pod all-reduce)
# ---------------------------------------------------------------------------

def compress_gradient(g: jax.Array, key: jax.Array, bits: int = 8) -> QTensor:
    """Stochastic-rounding int8 compression for cross-pod gradient reduce.

    Unbiased (E[q*scale] = g), so SGD/Adam convergence is preserved in
    expectation; per-tensor scale keeps it one collective-friendly buffer.
    """
    scale = compute_scale(g, bits=bits, axis=None)
    return quantize(g, bits=bits, axis=None, scale=scale,
                    stochastic=True, key=key)


def decompress_gradient(q: QTensor, dtype=jnp.float32) -> jax.Array:
    return q.dequantize(dtype)
