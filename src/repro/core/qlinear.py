"""Quantization-aware linear layers.

The framework's models are functional (param pytrees + apply fns).  Every
matmul in the model zoo goes through :func:`linear` so that post-training
quantization (`quant.quantize_tree`) transparently switches a model from the
bf16 training path to the paper's int8 serving path:

- fp weight (jnp array)      -> jnp dot in bf16 (training / baseline serving)
- QTensor weight             -> kernels.ops.qmatmul (w8a16 weight-only quant)
- QTensor weight + act_bits8 -> kernels.ops.qmatmul_dynamic (full w8a8 path)

The execution mode is carried in a `QuantMode` (static, hashable) so jitted
step functions specialize on it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class QuantMode:
    """Static quantization mode threaded through model apply fns."""
    enabled: bool = False          # weights are QTensors
    act_bits: int = 16             # 8 -> w8a8 integer path, else w8a16
    interpret: bool = False        # force Pallas interpreter (CPU validation)

    @property
    def w8a8(self) -> bool:
        return self.enabled and self.act_bits == 8


FP = QuantMode(enabled=False)
W8A16 = QuantMode(enabled=True, act_bits=16)
W8A8 = QuantMode(enabled=True, act_bits=8)


def init_linear(key, d_in: int, d_out: int, *, bias: bool = True,
                dtype=jnp.float32, scale: Optional[float] = None) -> dict:
    """Truncated-normal init, std = 1/sqrt(d_in) unless overridden."""
    std = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.truncated_normal(key, -2, 2, (d_in, d_out),
                                           jnp.float32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params: dict, x: jax.Array, *, activation: str = "none",
           mode: QuantMode = FP, compute_dtype=jnp.bfloat16) -> jax.Array:
    """y = act(x @ w + b), dispatching on the weight's quantization state."""
    w = params["w"]
    b = params.get("b")
    if isinstance(w, QTensor):
        fn = ops.qmatmul_dynamic if mode.w8a8 else ops.qmatmul
        return fn(x, w, b, activation=activation, out_dtype=x.dtype,
                  interpret=mode.interpret)
    # fp path: bf16 compute, fp32 accumulate (XLA default on MXU)
    y = jnp.dot(x.astype(compute_dtype), w.astype(compute_dtype),
                preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    elif activation == "silu":
        y = y * jax.nn.sigmoid(y)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif activation != "none":
        raise ValueError(activation)
    return y.astype(x.dtype)
