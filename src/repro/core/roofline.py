"""Roofline-term derivation from compiled XLA artifacts.

The paper's §4 adapts the Roofline model to NN accelerators (ops per byte of
weight memory).  This module applies the same methodology to the *new*
system: for every (arch x shape x mesh) dry-run cell we derive three roofline
terms from the compiled artifact — no hardware required:

    compute_s    = HLO_FLOPs  / (chips * PEAK_FLOPS)
    memory_s     = HLO_bytes  / (chips * HBM_BW)
    collective_s = sum(collective operand bytes) / (chips * ICI_BW)

All three inputs come from ``core.hlo_cost``'s structural HLO analysis
(trip-count-aware, slice-aware, collective-aware), which also supplies the
per-op FLOP/byte breakdown carried on :class:`RooflineTerms` so reports can
show *where* the counts come from.

Hardware model: TPU v5e — 197 TFLOP/s bf16 (394 TOPS int8), 819 GB/s HBM,
~50 GB/s per ICI link.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9   # per link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def op_rows_from_by_op(by_op: Optional[Dict[str, Dict[str, float]]],
                       limit: Optional[int] = None):
    """(opcode, flops, bytes, count) rows from a by_op dict (as produced by
    CostTotals.by_op_dict / RooflineTerms.to_dict), heaviest first."""
    if not by_op:
        return []
    rows = sorted(
        ((op, d.get("flops", 0.0), d.get("bytes", 0.0), d.get("count", 0.0))
         for op, d in by_op.items()),
        key=lambda r: (r[1], r[2]), reverse=True)
    return rows[:limit] if limit else rows


@dataclasses.dataclass
class RooflineTerms:
    """The three roofline terms for one (arch x shape x mesh) cell."""
    cell: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    model_flops: float = 0.0           # 6*N*D etc., "useful" flops
    peak_flops: float = PEAK_FLOPS_BF16
    bytes_per_device: Optional[dict] = None
    # per-op breakdown from hlo_cost (global = per-device x chips):
    # opcode -> {"flops": .., "bytes": .., "count": ..}
    by_op: Optional[Dict[str, Dict[str, float]]] = None
    collective_bytes_by_op: Optional[Dict[str, float]] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: terms overlap, the max dominates."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundant compute."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the hardware roofline achieved if the step ran at its
        dominant term: useful model flops per second / peak."""
        if self.step_s == 0:
            return 0.0
        return (self.model_flops / self.step_s) / (self.chips * self.peak_flops)

    def op_rows(self, limit: Optional[int] = None):
        """(opcode, flops, bytes, count) heaviest-first, from by_op."""
        return op_rows_from_by_op(self.by_op, limit)

    def to_dict(self) -> dict:
        return {
            "cell": self.cell, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_counts": self.collective_counts,
            "collective_bytes_by_op": self.collective_bytes_by_op,
            "by_op": self.by_op,
            "model_flops": self.model_flops,
            "peak_flops": self.peak_flops,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def from_compiled(cell: str, compiled, chips: int, *,
                  model_flops: float = 0.0,
                  peak_flops: float = PEAK_FLOPS_BF16,
                  hlo_text: Optional[str] = None) -> RooflineTerms:
    """Build RooflineTerms from a jax Compiled object.

    The compiled module is the per-device SPMD program, and XLA's own
    cost_analysis counts while-loop (scan) bodies once — so the roofline
    inputs come from `core.hlo_cost` (structural trip-count-aware HLO
    analysis), scaled to global by the chip count.
    """
    text = hlo_text if hlo_text is not None else compiled.as_text()
    from repro.core import hlo_cost as HC
    totals = HC.analyze(text)
    by_op = {op: {"flops": oc.flops * chips, "bytes": oc.bytes * chips,
                  "count": oc.count}
             for op, oc in totals.by_op.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                ma, "generated_code_size_in_bytes", None),
        }
    except Exception:
        pass
    return RooflineTerms(
        cell=cell, chips=chips,
        hlo_flops=totals.flops * chips,       # per-device program -> global
        hlo_bytes=totals.bytes * chips,
        collective_bytes=totals.collective_bytes * chips,
        collective_counts={k: int(v)
                           for k, v in totals.collective_counts.items()},
        collective_bytes_by_op={k: v * chips
                                for k, v in
                                totals.collective_bytes_by_op.items()},
        by_op=by_op, model_flops=model_flops,
        peak_flops=peak_flops, bytes_per_device=mem)


def save_report(terms: List[RooflineTerms], path: str) -> None:
    with open(path, "w") as f:
        json.dump([t.to_dict() for t in terms], f, indent=1)


def load_report(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)
