"""Roofline-term derivation from compiled XLA artifacts.

The paper's §4 adapts the Roofline model to NN accelerators (ops per byte of
weight memory).  This module applies the same methodology to the *new*
system: for every (arch x shape x mesh) dry-run cell we derive three roofline
terms from the compiled artifact — no hardware required:

    compute_s    = HLO_FLOPs  / (chips * PEAK_FLOPS)
    memory_s     = HLO_bytes  / (chips * HBM_BW)
    collective_s = sum(collective operand bytes) / (chips * ICI_BW)

`compiled.cost_analysis()` provides FLOPs and bytes; collective bytes are
parsed from the post-SPMD-partitioning HLO text (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand sizes).

Hardware model: TPU v5e — 197 TFLOP/s bf16 (394 TOPS int8), 819 GB/s HBM,
~50 GB/s per ICI link.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9   # per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]+?)\s+"
                     r"([\w\-]+)\(")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective op in (post-SPMD) HLO text.

    Two passes: first map instruction name -> result type (operand sizes are
    looked up from the defining instruction), then for each collective line,
    sum its operands' sizes.  Falls back to the collective's own result size
    when an operand can't be resolved (conservative for all-gather, exact
    for all-reduce/permute).
    """
    defs: Dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2).strip()

    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    bytes_by_op: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, result_type, opcode = m.groups()
        base = opcode
        for op in COLLECTIVE_OPS:
            if base == op or base.startswith(op + "-"):  # e.g. all-gather-start
                if base.endswith("-done"):
                    break  # counted at -start
                counts[op] += 1
                # operand list: text inside the first (...) after opcode
                paren = line[line.index(opcode + "(") + len(opcode) + 1:]
                depth, args, cur = 1, [], []
                for ch in paren:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    if ch == "," and depth == 1:
                        args.append("".join(cur))
                        cur = []
                    else:
                        cur.append(ch)
                if cur:
                    args.append("".join(cur))
                got = 0
                for a in args:
                    a = a.strip().lstrip("%")
                    # operands may carry inline types: "bf16[8,128] %x"
                    b = _shape_bytes(a)
                    if b == 0:
                        b = _shape_bytes(defs.get(a.split(" ")[-1], ""))
                    got += b
                if got == 0:
                    got = _shape_bytes(result_type)
                bytes_by_op[op] += got
                break
    return CollectiveStats(counts=counts, bytes_by_op=bytes_by_op)


@dataclasses.dataclass
class RooflineTerms:
    """The three roofline terms for one (arch x shape x mesh) cell."""
    cell: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    model_flops: float = 0.0           # 6*N*D etc., "useful" flops
    peak_flops: float = PEAK_FLOPS_BF16
    bytes_per_device: Optional[dict] = None

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * self.peak_flops)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time: terms overlap, the max dominates."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundant compute."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the hardware roofline achieved if the step ran at its
        dominant term: useful model flops per second / peak."""
        if self.step_s == 0:
            return 0.0
        return (self.model_flops / self.step_s) / (self.chips * self.peak_flops)

    def to_dict(self) -> dict:
        return {
            "cell": self.cell, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_counts": self.collective_counts,
            "model_flops": self.model_flops,
            "peak_flops": self.peak_flops,
            "bytes_per_device": self.bytes_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }


def from_compiled(cell: str, compiled, chips: int, *,
                  model_flops: float = 0.0,
                  peak_flops: float = PEAK_FLOPS_BF16,
                  hlo_text: Optional[str] = None) -> RooflineTerms:
    """Build RooflineTerms from a jax Compiled object.

    The compiled module is the per-device SPMD program, and XLA's own
    cost_analysis counts while-loop (scan) bodies once — so the roofline
    inputs come from `core.hlo_cost` (trip-count-aware HLO walk), scaled to
    global by the chip count.
    """
    text = hlo_text if hlo_text is not None else compiled.as_text()
    from repro.core import hlo_cost as HC
    totals = HC.analyze(text)
    flops = totals.flops * chips      # per-device program -> global
    byts = totals.bytes * chips
    coll = CollectiveStats(
        counts={k: int(v) for k, v in totals.collective_counts.items()},
        bytes_by_op={"all": int(totals.collective_bytes * chips)})
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                ma, "generated_code_size_in_bytes", None),
        }
    except Exception:
        pass
    return RooflineTerms(
        cell=cell, chips=chips, hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(coll.total_bytes),
        collective_counts=coll.counts, model_flops=model_flops,
        peak_flops=peak_flops, bytes_per_device=mem)


def save_report(terms: List[RooflineTerms], path: str) -> None:
    with open(path, "w") as f:
        json.dump([t.to_dict() for t in terms], f, indent=1)


def load_report(path: str) -> List[dict]:
    with open(path) as f:
        return json.load(f)
