"""Optimizers built in-tree (no optax): AdamW and Adafactor.

AdamW keeps fp32 m/v (and fp32 master weights when params are bf16) — the
standard large-scale recipe.  Adafactor keeps factored second moments
(row/col) for the big 2-D weights, cutting optimizer memory from 2x to ~0x —
the option used for the largest dry-run cells.

All state is a pytree mirroring the params, so the sharding rules shard it
exactly like the parameters (FSDP), and checkpoints treat it uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return tree_map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / max(1, warmup))
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    m: object
    v: object
    master: object          # fp32 master copy when params are low-precision


def adamw_init(params, *, keep_master: bool = True) -> AdamWState:
    zeros = tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (tree_map(lambda p: p.astype(jnp.float32), params)
              if keep_master else None)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      tree_map(jnp.zeros_like, zeros), master)


def adamw_update(params, grads, state: AdamWState, *,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    m = tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                 state.m, grads)
    v = tree_map(lambda v_, g: b2 * v_ + (1 - b2)
                 * jnp.square(g.astype(jnp.float32)), state.v, grads)
    base = state.master if state.master is not None else params

    def upd(p, m_, v_):
        mhat = m_ / bc1
        vhat = v_ / bc2
        return (p.astype(jnp.float32)
                - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                          + weight_decay * p.astype(jnp.float32)))

    new_master = tree_map(upd, base, m, v)
    new_params = tree_map(lambda nm, p: nm.astype(p.dtype),
                          new_master, params)
    return new_params, AdamWState(
        step, m, v, new_master if state.master is not None else None)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; no momentum by default)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jax.Array
    vr: object     # row second-moment (or full v for <2D leaves)
    vc: object     # col second-moment (None entries for <2D leaves)


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def adafactor_init(params) -> AdafactorState:
    def vr_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)   # placeholder

    return AdafactorState(jnp.zeros((), jnp.int32),
                          tree_map(vr_init, params),
                          tree_map(vc_init, params))


def adafactor_update(params, grads, state: AdafactorState, *,
                     lr, decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0,
                     weight_decay: float = 0.0):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -decay

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if _factored(p):
            new_vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            new_vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            r = new_vr / jnp.mean(new_vr, axis=-1, keepdims=True)
            update = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :])
        else:
            new_vr = beta * vr + (1 - beta) * g2
            new_vc = vc
            update = g / jnp.sqrt(new_vr)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + 1e-12)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        newp = (p.astype(jnp.float32) - lr_t * update
                - lr_t * weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), new_vr, new_vc

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_vr = tdef.flatten_up_to(state.vr)
    flat_vc = tdef.flatten_up_to(state.vc)
    outs = [upd(p, g, vr, vc) for p, g, vr, vc
            in zip(flat_p, flat_g, flat_vr, flat_vc)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_vr = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    new_vc = jax.tree_util.tree_unflatten(tdef, [o[2] for o in outs])
    return new_params, AdafactorState(step, new_vr, new_vc)


# ---------------------------------------------------------------------------
# uniform facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable


def make_optimizer(name: str, *, lr, weight_decay: float = 0.1,
                   keep_master: bool = False) -> Optimizer:
    if name == "adamw":
        return Optimizer(
            "adamw",
            lambda p: adamw_init(p, keep_master=keep_master),
            lambda p, g, s: adamw_update(p, g, s, lr=lr,
                                         weight_decay=weight_decay))
    if name == "adafactor":
        return Optimizer(
            "adafactor",
            adafactor_init,
            lambda p, g, s: adafactor_update(p, g, s, lr=lr,
                                             weight_decay=weight_decay))
    raise ValueError(name)
