from repro.optim.optimizers import (AdamWState, adamw_init, adamw_update,
                                    AdafactorState, adafactor_init,
                                    adafactor_update, clip_by_global_norm,
                                    cosine_schedule, Optimizer, make_optimizer)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "AdafactorState",
           "adafactor_init", "adafactor_update", "clip_by_global_norm",
           "cosine_schedule", "Optimizer", "make_optimizer"]
