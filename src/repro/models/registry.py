"""Family -> model module dispatch.

Uniform API per family:
    init(key, cfg, dtype) -> params
    forward(params, tokens, [extra_embeds,] cfg, *, mode, remat) -> logits
    init_cache(cfg, batch, s_max, dtype) -> cache
    decode_step(params, tokens, cache, cache_index, cfg, *, mode)
        -> (logits, cache)

``apply_forward`` / ``apply_decode`` normalize the extra-input plumbing
(encoder frames / vision patches) so the runtime treats all ten archs
identically.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode
from repro.models import encdec, moe, rglru, ssm, transformer, vision

_MODULES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
    "vlm": vision,
}


def module_for(cfg: ArchConfig):
    return _MODULES[cfg.family]


def init(key, cfg: ArchConfig, dtype=None):
    import jax.numpy as jnp
    return module_for(cfg).init(key, cfg, dtype or jnp.float32)


def apply_forward(params, cfg: ArchConfig, batch: dict, *,
                  mode: QuantMode = FP, remat: bool = True):
    """batch: dict from input_specs (tokens + optional modality embeds)."""
    m = module_for(cfg)
    if cfg.family == "encdec":
        return m.forward(params, batch["tokens"], batch["encoder_embeds"],
                         cfg, mode=mode, remat=remat)
    if cfg.family == "vlm":
        return m.forward(params, batch["tokens"], batch["vision_embeds"],
                         cfg, mode=mode, remat=remat)
    return m.forward(params, batch["tokens"], cfg, mode=mode, remat=remat)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None):
    import jax.numpy as jnp
    return module_for(cfg).init_cache(cfg, batch, s_max,
                                      dtype or jnp.bfloat16)


def apply_decode(params, cfg: ArchConfig, batch: dict, cache, *,
                 mode: QuantMode = FP):
    m = module_for(cfg)
    return m.decode_step(params, batch["tokens"], cache,
                         batch["cache_index"], cfg, mode=mode)


# ---------------------------------------------------------------------------
# slot-engine contract (per-row decode state; see docs/serving.md)
# ---------------------------------------------------------------------------

def cache_batch_axes(cfg: ArchConfig, cache: dict) -> dict:
    """Batch (slot) axis per cache leaf.  Families whose cache stacks
    extra leading dims (hybrid groups) override ``cache_batch_axes`` in
    their module; everyone else keeps batch right behind the layer axis."""
    m = module_for(cfg)
    if hasattr(m, "cache_batch_axes"):
        return m.cache_batch_axes(cache)
    return {k: 1 for k in cache}


def mask_inactive_slots(cfg: ArchConfig, old_cache: dict, new_cache: dict,
                        active):
    """Slot-engine isolation hook: return ``new_cache`` with inactive
    rows' *non-positional* state restored from ``old_cache``.

    KV caches need nothing here — stale positional entries are invisible
    behind each row's ``valid_len`` frontier — so the dense/moe families
    return ``new_cache`` unchanged and pay zero extra traffic.  Recurrent
    families (ssm/hybrid) define ``mask_inactive_slots`` in their module:
    their state has no frontier to hide behind, so inactive rows must be
    frozen bitwise."""
    m = module_for(cfg)
    if hasattr(m, "mask_inactive_slots"):
        return m.mask_inactive_slots(old_cache, new_cache, active)
    return new_cache
