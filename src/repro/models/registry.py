"""Family -> model module dispatch.

Uniform API per family:
    init(key, cfg, dtype) -> params
    forward(params, tokens, [extra_embeds,] cfg, *, mode, remat) -> logits
    init_cache(cfg, batch, s_max, dtype) -> cache
    decode_step(params, tokens, cache, cache_index, cfg, *, mode)
        -> (logits, cache)

``apply_forward`` / ``apply_decode`` normalize the extra-input plumbing
(encoder frames / vision patches) so the runtime treats all ten archs
identically.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode
from repro.models import encdec, moe, rglru, ssm, transformer, vision

_MODULES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
    "vlm": vision,
}


def module_for(cfg: ArchConfig):
    return _MODULES[cfg.family]


def init(key, cfg: ArchConfig, dtype=None):
    import jax.numpy as jnp
    return module_for(cfg).init(key, cfg, dtype or jnp.float32)


def apply_forward(params, cfg: ArchConfig, batch: dict, *,
                  mode: QuantMode = FP, remat: bool = True):
    """batch: dict from input_specs (tokens + optional modality embeds)."""
    m = module_for(cfg)
    if cfg.family == "encdec":
        return m.forward(params, batch["tokens"], batch["encoder_embeds"],
                         cfg, mode=mode, remat=remat)
    if cfg.family == "vlm":
        return m.forward(params, batch["tokens"], batch["vision_embeds"],
                         cfg, mode=mode, remat=remat)
    return m.forward(params, batch["tokens"], cfg, mode=mode, remat=remat)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None):
    import jax.numpy as jnp
    return module_for(cfg).init_cache(cfg, batch, s_max,
                                      dtype or jnp.bfloat16)


def apply_decode(params, cfg: ArchConfig, batch: dict, cache, *,
                 mode: QuantMode = FP):
    m = module_for(cfg)
    return m.decode_step(params, batch["tokens"], cache,
                         batch["cache_index"], cfg, mode=mode)
