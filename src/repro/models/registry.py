"""Family -> model module dispatch.

Uniform API per family:
    init(key, cfg, dtype) -> params
    forward(params, tokens, [extra_embeds,] cfg, *, mode, remat) -> logits
    init_cache(cfg, batch, s_max, dtype) -> cache
    decode_step(params, tokens, cache, cache_index, cfg, *, mode)
        -> (logits, cache)

``apply_forward`` / ``apply_decode`` normalize the extra-input plumbing
(encoder frames / vision patches) so the runtime treats all ten archs
identically.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode
from repro.models import encdec, moe, rglru, ssm, transformer, vision

_MODULES = {
    "dense": transformer,
    "moe": moe,
    "ssm": ssm,
    "hybrid": rglru,
    "encdec": encdec,
    "vlm": vision,
}


def module_for(cfg: ArchConfig):
    return _MODULES[cfg.family]


def init(key, cfg: ArchConfig, dtype=None):
    import jax.numpy as jnp
    return module_for(cfg).init(key, cfg, dtype or jnp.float32)


def apply_forward(params, cfg: ArchConfig, batch: dict, *,
                  mode: QuantMode = FP, remat: bool = True):
    """batch: dict from input_specs (tokens + optional modality embeds)."""
    m = module_for(cfg)
    if cfg.family == "encdec":
        return m.forward(params, batch["tokens"], batch["encoder_embeds"],
                         cfg, mode=mode, remat=remat)
    if cfg.family == "vlm":
        return m.forward(params, batch["tokens"], batch["vision_embeds"],
                         cfg, mode=mode, remat=remat)
    return m.forward(params, batch["tokens"], cfg, mode=mode, remat=remat)


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None):
    import jax.numpy as jnp
    return module_for(cfg).init_cache(cfg, batch, s_max,
                                      dtype or jnp.bfloat16)


def apply_decode(params, cfg: ArchConfig, batch: dict, cache, *,
                 mode: QuantMode = FP):
    m = module_for(cfg)
    return m.decode_step(params, batch["tokens"], cache,
                         batch["cache_index"], cfg, mode=mode)


def supports_paging(cfg: ArchConfig) -> bool:
    """True when the family can serve from a paged (block-table) KV
    cache: it must have a growing positional KV frontier (excludes the
    recurrent ssm/hybrid state) and full attention (a sliding window's
    ring overwrite has no stable position -> block mapping)."""
    return (cfg.window is None
            and hasattr(module_for(cfg), "init_paged_cache"))


def init_paged_cache(cfg: ArchConfig, num_slots: int, s_max: int,
                     block_size: int, num_blocks: int, dtype=None):
    """Paged KV cache: positional leaves become physical blocks
    (..., num_blocks, block_size, KV, hd) shared by all slots through the
    per-slot ``cache["block_tables"]`` (num_slots, s_max // block_size)
    int32 leaf; block 0 is the reserved trash block.  Non-positional
    leaves (primed cross K/V, xlen) stay slot-resident."""
    import jax.numpy as jnp
    if not supports_paging(cfg):
        raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                         f"does not support the paged KV cache")
    return module_for(cfg).init_paged_cache(cfg, num_slots, s_max,
                                            block_size, num_blocks,
                                            dtype or jnp.bfloat16)


def paged_block_axes(cfg: ArchConfig, cache: dict) -> dict:
    """Physical-block (NB) axis per PAGED cache leaf — the axis a block
    table entry indexes.  Leaves absent from this dict (cross K/V, xlen,
    the table itself) are slot-resident and keep cache_batch_axes
    semantics."""
    return module_for(cfg).paged_block_axes(cache)


# ---------------------------------------------------------------------------
# slot-engine contract (per-row decode state; see docs/serving.md)
# ---------------------------------------------------------------------------

def needs_prime(cfg: ArchConfig) -> bool:
    """True when the family decodes against per-request primed state
    (encoder frames / vision patches) that must be written into a slot
    row at admission by a prime dispatch (encdec/vlm)."""
    return hasattr(module_for(cfg), "prime_slot")


def source_len(cfg: ArchConfig) -> int:
    """Static source length of a prime dispatch: how many frames/patches
    one slot row's primed cross-K/V holds (0 for token-only families)."""
    if cfg.family == "encdec":
        return cfg.enc_seq
    if cfg.family == "vlm":
        return cfg.n_patches
    return 0


def source_shape(cfg: ArchConfig) -> Optional[tuple]:
    """(source_len, d_model) of one request's source embeddings, or None
    for token-only families — the single contract request generators
    (serve CLI, benches, tests) build per-request sources against."""
    if not needs_prime(cfg):
        return None
    return (source_len(cfg), cfg.d_model)


def prime_slot(cfg: ArchConfig, params, source, n_valid, *,
               mode: QuantMode = FP) -> dict:
    """Run one request's encoder / vision tower and return the
    slot-resident primed leaves (pre-projected cross K/V + the row's
    ``xlen`` frontier) that a prime dispatch scatters into the pooled
    cache at the slot's row.  ``source`` is (1, source_len(cfg), D)
    padded to the static length; ``n_valid`` () is how many positions
    are real (decode masks reads past it)."""
    return module_for(cfg).prime_slot(params, source, n_valid, cfg,
                                      mode=mode)


def supports_speculation(cfg: ArchConfig) -> bool:
    """True when the family can serve as the TARGET (or the draft) of
    draft-and-verify speculative decoding: its entire decode state must
    be positional KV behind a ``valid_len`` frontier, so a rejected
    speculative tail can be *rewound* by resetting ``cache_index`` — the
    stale writes die by overwrite-before-read (decode-contract rule 7,
    docs/architecture.md).  That excludes the recurrent families
    (ssm/hybrid: ``h``/conv state advances irreversibly through rejected
    tokens), sliding-window attention (the ring overwrite destroys the
    positions a rewind must restore), and the prime families (their
    cross-attention plumbing is not wired through the verify scan)."""
    return (cfg.window is None and not needs_prime(cfg)
            and hasattr(module_for(cfg), "draft_params"))


def supports_self_draft(cfg: ArchConfig) -> bool:
    """True when the family can draft for itself with a truncated-layer
    view of its own params (no second checkpoint): it must be
    speculation-capable AND expose ``draft_params`` — a module-level
    slice of the vmap-stacked ``layers`` leaves."""
    return supports_speculation(cfg)


def draft_config(cfg: ArchConfig, n_layers: int) -> ArchConfig:
    """The self-draft model's config: the target's, truncated to its
    first ``n_layers`` layers."""
    import dataclasses
    if not 1 <= n_layers <= cfg.n_layers:
        raise ValueError(
            f"draft_layers must be in [1, n_layers={cfg.n_layers}], "
            f"got {n_layers}")
    return dataclasses.replace(cfg, name=f"{cfg.name}-draft{n_layers}",
                               n_layers=n_layers)


def draft_params(cfg: ArchConfig, params, n_layers: int):
    """The self-draft model's params: the target's, with the stacked
    ``layers`` leaves sliced to ``[:n_layers]`` (embed/norm/unembed
    shared by reference — zero extra weight memory)."""
    if not supports_self_draft(cfg):
        raise ValueError(f"family {cfg.family!r} (window={cfg.window}) "
                         f"does not support self-draft speculation")
    return module_for(cfg).draft_params(params, n_layers)


def cache_batch_axes(cfg: ArchConfig, cache: dict) -> dict:
    """Batch (slot) axis per cache leaf.  Families whose cache stacks
    extra leading dims (hybrid groups) override ``cache_batch_axes`` in
    their module; everyone else keeps batch right behind the layer axis."""
    m = module_for(cfg)
    if hasattr(m, "cache_batch_axes"):
        return m.cache_batch_axes(cache)
    return {k: 1 for k in cache}


def mask_inactive_slots(cfg: ArchConfig, old_cache: dict, new_cache: dict,
                        active):
    """Slot-engine isolation hook: return ``new_cache`` with inactive
    rows' *non-positional* state restored from ``old_cache``.

    KV caches need nothing here — stale positional entries are invisible
    behind each row's ``valid_len`` frontier — so the dense/moe families
    return ``new_cache`` unchanged and pay zero extra traffic.  Recurrent
    families (ssm/hybrid) define ``mask_inactive_slots`` in their module:
    their state has no frontier to hide behind, so inactive rows must be
    frozen bitwise."""
    m = module_for(cfg)
    if hasattr(m, "mask_inactive_slots"):
        return m.mask_inactive_slots(old_cache, new_cache, active)
    return new_cache
