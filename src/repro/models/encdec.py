"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, enc_seq, D).  The backbone is real:
- encoder: bidirectional transformer (LayerNorm, GeLU MLP, sinusoidal pos);
- decoder: causal self-attention + cross-attention to the encoder output +
  GeLU MLP, learned positional embeddings.
No RoPE (Whisper uses absolute positions).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode, linear
from repro.models import layers as L
from repro.runtime.sharding import constrain

Array = jax.Array

MAX_DEC_POS = 1 << 20   # learned dec positions are table[pos % table_len]
DEC_POS_TABLE = 4096


def _attn_cfg(cfg: ArchConfig, causal: bool) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, causal=causal, use_rope=False)


def _sinusoid(s: int, d: int) -> Array:
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_enc_layer(key, cfg, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": L.init_layernorm(cfg.d_model, dtype),
        "attn": L.init_attention(k1, _attn_cfg(cfg, causal=False), dtype),
        "ln_mlp": L.init_layernorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False,
                          activation="gelu", dtype=dtype),
    }


def init_dec_layer(key, cfg, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": L.init_layernorm(cfg.d_model, dtype),
        "self_attn": L.init_attention(k1, _attn_cfg(cfg, causal=True), dtype),
        "ln_cross": L.init_layernorm(cfg.d_model, dtype),
        "cross_attn": L.init_attention(k2, _attn_cfg(cfg, causal=False),
                                       dtype),
        "ln_mlp": L.init_layernorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False,
                          activation="gelu", dtype=dtype),
    }


def init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "dec_pos": (jax.random.normal(kp, (DEC_POS_TABLE, cfg.d_model),
                                      jnp.float32) * 0.01).astype(dtype),
        "enc_layers": jax.vmap(
            lambda k: init_enc_layer(k, cfg, dtype))(enc_keys),
        "ln_enc": L.init_layernorm(cfg.d_model, dtype),
        "dec_layers": jax.vmap(
            lambda k: init_dec_layer(k, cfg, dtype))(dec_keys),
        "ln_f": L.init_layernorm(cfg.d_model, dtype),
    }


def encode(params: dict, frame_embeds: Array, cfg: ArchConfig, *,
           mode: QuantMode = FP, remat: bool = True) -> Array:
    """frame_embeds: (B, enc_seq, D) — the stubbed conv-frontend output."""
    b, s, d = frame_embeds.shape
    x = frame_embeds + _sinusoid(s, d)[None].astype(frame_embeds.dtype)
    x = constrain(x, "act")
    acfg = _attn_cfg(cfg, causal=False)

    def body(x, lp):
        h = L.layernorm(lp["ln_attn"], x)
        a, _ = L.attention(lp["attn"], h, acfg, mode=mode)
        x = x + a
        h = L.layernorm(lp["ln_mlp"], x)
        x = x + L.mlp(lp["mlp"], h, gated=False, activation="gelu",
                      mode=mode)
        return constrain(x, "act"), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(params["ln_enc"], x)


def _dec_layer(cfg, mode, lp, x, enc_out, positions, kv_cache=None,
               cache_index=None, valid_len=None, xattn_precomputed=None,
               xattn_valid_len=None, block_tables=None):
    acfg_s = _attn_cfg(cfg, causal=True)
    acfg_x = _attn_cfg(cfg, causal=False)
    h = L.layernorm(lp["ln_self"], x)
    a, new_kv = L.attention(lp["self_attn"], h, acfg_s, mode=mode,
                            positions=positions, kv_cache=kv_cache,
                            cache_index=cache_index, valid_len=valid_len,
                            block_tables=block_tables)
    x = x + a
    h = L.layernorm(lp["ln_cross"], x)
    a, _ = L.attention(lp["cross_attn"], h, acfg_x, mode=mode,
                       xattn_kv=None if xattn_precomputed else enc_out,
                       xattn_precomputed=xattn_precomputed,
                       xattn_valid_len=xattn_valid_len)
    x = x + a
    h = L.layernorm(lp["ln_mlp"], x)
    x = x + L.mlp(lp["mlp"], h, gated=False, activation="gelu", mode=mode)
    return constrain(x, "act"), new_kv


def forward(params: dict, tokens: Array, encoder_embeds: Array,
            cfg: ArchConfig, *, mode: QuantMode = FP,
            remat: bool = True) -> Array:
    """Teacher-forced decode over the full target sequence (train/prefill)."""
    enc_out = encode(params, encoder_embeds, cfg, mode=mode, remat=remat)
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    pos_emb = params["dec_pos"][jnp.arange(s) % DEC_POS_TABLE]
    x = x + pos_emb[None].astype(x.dtype)
    positions = jnp.arange(s)[None, :]

    def body(x, lp):
        out, _ = _dec_layer(cfg, mode, lp, x, enc_out, positions)
        return out, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layernorm(params["ln_f"], x)
    return L.unembed(params["embed"], x)


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Self-attention KV cache + PRE-PROJECTED cross-attention K/V.

    §Perf iteration D: the encoder output is static across decode steps,
    so each decoder layer's cross K/V projections run once at prime time —
    the per-step decode never touches enc_out or the wk/wv matmuls
    (baseline: recomputed every step for every layer).

    ``xlen`` (B,) is the per-row cross frontier: decode masks each row's
    source reads at its own primed length, so the slot engine can hold a
    different request's source per row.  It initializes to the full
    static source length so un-primed batchwide flows keep attending the
    whole (zero) source, exactly as before."""
    k, v = L.init_kv_cache(batch, s_max, cfg.n_kv_heads, cfg.head_dim, dtype)
    zeros = jnp.zeros((cfg.n_layers,) + k.shape, dtype)
    xshape = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads,
              cfg.head_dim)
    return {"k": zeros, "v": jnp.zeros_like(zeros),
            "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
            "xlen": jnp.full((batch,), cfg.enc_seq, jnp.int32)}


def init_paged_cache(cfg: ArchConfig, num_slots: int, s_max: int,
                     block_size: int, num_blocks: int,
                     dtype=jnp.bfloat16) -> dict:
    """Paged self-attention KV (physical blocks (L, NB, bs, KV, hd) + a
    per-slot block table); cross-attention K/V stays slot-resident — the
    primed source row is written whole at admission and has no growing
    positional frontier to page."""
    if s_max % block_size:
        raise ValueError(f"s_max={s_max} must tile into whole blocks of "
                         f"{block_size}")
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads,
             cfg.head_dim)
    xshape = (cfg.n_layers, num_slots, cfg.enc_seq, cfg.n_kv_heads,
              cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
            "xlen": jnp.full((num_slots,), cfg.enc_seq, jnp.int32),
            "block_tables": jnp.zeros((num_slots, s_max // block_size),
                                      jnp.int32)}


def paged_block_axes(cache: dict) -> dict:
    """Physical-block (NB) axis per PAGED leaf; xk/xv/xlen stay
    slot-resident (see init_paged_cache)."""
    return {"k": 1, "v": 1}


def cache_batch_axes(cache: dict) -> dict:
    """Batch (slot) axis per cache leaf: layer-stacked leaves keep batch
    at axis 1; the per-row cross frontier ``xlen`` and the per-slot block
    table ARE batch-leading."""
    return {k: (0 if k in ("xlen", "block_tables") else 1) for k in cache}


def _cross_kv(params, enc_out, cfg, *, mode=FP):
    """Pre-project every decoder layer's cross K/V from encoder output
    (shared by the batchwide prime and the engine's per-slot prime)."""
    b, se, d = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim

    def project(_, lp):
        xk = linear(lp["cross_attn"]["wk"], enc_out,
                    mode=mode).reshape(b, se, kvh, hd)
        xv = linear(lp["cross_attn"]["wv"], enc_out,
                    mode=mode).reshape(b, se, kvh, hd)
        return None, (xk, xv)

    _, (xk, xv) = jax.lax.scan(project, None, params["dec_layers"])
    return xk, xv


def prime_cache(params, cache, encoder_embeds, cfg, *, mode=FP):
    """Run the encoder once and pre-project every decoder layer's cross
    K/V; decode steps reuse both."""
    enc_out = encode(params, encoder_embeds, cfg, mode=mode)
    xk, xv = _cross_kv(params, enc_out, cfg, mode=mode)
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype),
                xlen=jnp.full((enc_out.shape[0],), enc_out.shape[1],
                              jnp.int32))


def prime_slot(params, source, n_valid, cfg, *, mode=FP):
    """Per-request prime for the slot engine: encode ONE request's source
    (``source`` (1, enc_seq, D), padded to the static length) and return
    the slot-resident leaves a prime dispatch scatters into row ``sid``
    of the pooled cache — pre-projected cross K/V plus the row's cross
    frontier ``n_valid`` (decode masks cross reads past it).  The
    encoder attends over the full padded input — Whisper's own
    pad-to-30s recipe, so frames near the frontier legitimately see the
    zero pad; what the frontier guarantees is that K/V *past* it (pad
    projections or a previous tenant's stale tail) is never read.
    No remat: priming is inference, there is no backward pass."""
    enc_out = encode(params, source, cfg, mode=mode, remat=False)
    xk, xv = _cross_kv(params, enc_out, cfg, mode=mode)
    return {"xk": xk, "xv": xv,
            "xlen": jnp.asarray(n_valid, jnp.int32).reshape(1)}


def decode_step(params: dict, tokens: Array, cache: dict, cache_index: Array,
                cfg: ArchConfig, *, mode: QuantMode = FP
                ) -> Tuple[Array, dict]:
    """One decode step.  ``cache_index`` is scalar () (lockstep batch) or
    (B,) per-row for the slot engine: learned decoder positions, cache
    writes and self-attention masks become per-row, and every row's
    cross-attention reads mask at its OWN primed frontier
    (``cache["xlen"]``) — the per-slot primed cross-K/V contract."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    cache_index = jnp.asarray(cache_index)
    if cache_index.ndim:                    # (B,): per-slot positions
        pos_ids = (cache_index[:, None] + jnp.arange(s)[None, :]) \
            % DEC_POS_TABLE
        x = x + params["dec_pos"][pos_ids].astype(x.dtype)
        positions = cache_index[:, None] + jnp.arange(s)[None, :]
    else:
        pos_ids = (cache_index + jnp.arange(s)) % DEC_POS_TABLE
        x = x + params["dec_pos"][pos_ids][None].astype(x.dtype)
        positions = cache_index + jnp.arange(s)[None, :]

    # per-row cross frontier only on the slot-engine (vector) path: the
    # lockstep batch primed batchwide attends exactly what it primed, so
    # masking is a no-op there and would only disable the TPU flash
    # cross-attention kernel
    xlen = cache["xlen"] if cache_index.ndim else None
    tables = cache.get("block_tables")      # (B, MB) int32: paged mode

    def body(x, lp_and_kv):
        lp, ck, cv, xk, xv = lp_and_kv
        out, new_kv = _dec_layer(cfg, mode, lp, x, None, positions,
                                 kv_cache=(ck, cv), cache_index=cache_index,
                                 xattn_precomputed=(xk, xv),
                                 xattn_valid_len=xlen, block_tables=tables)
        return out, new_kv

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.layernorm(params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    if tables is not None:
        # paged: the scan emitted only the new-token entries (L, B, 1, ...)
        # — scatter them through each row's table into the physical pool
        return logits, dict(
            cache,
            k=L.paged_append(cache["k"], nk, tables, cache_index,
                             block_axis=1),
            v=L.paged_append(cache["v"], nv, tables, cache_index,
                             block_axis=1))
    return logits, dict(cache, k=nk, v=nv)
