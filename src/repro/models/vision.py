"""Llama-3.2-Vision-style VLM backbone [hf:meta-llama/Llama-3.2-Vision].

The vision encoder is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, n_patches, D).  The language backbone is
real: 100 decoder layers, with a *gated cross-attention* layer inserted
every ``xattn_every``-th layer (tanh-gated, zero-init — the Flamingo/Llama
recipe so the LM is unperturbed at init).

Scan structure: groups of ``xattn_every`` layers — (xattn_every - 1) pure
self-attention layers + 1 self+cross layer — so compile time stays
depth-independent.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode
from repro.models import layers as L
from repro.models import transformer as TF
from repro.runtime.sharding import constrain

Array = jax.Array


def _xattn_cfg(cfg: ArchConfig) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, causal=False, use_rope=False)


def init_xattn_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    p = TF.init_layer(k1, cfg, dtype)
    p["ln_x"] = TF._norm_init(cfg)(cfg.d_model, dtype)
    p["xattn"] = L.init_attention(k2, _xattn_cfg(cfg), dtype)
    p["x_gate"] = jnp.zeros((), jnp.float32)   # tanh-gated, zero-init
    return p


def _layout(cfg: ArchConfig) -> Tuple[int, int]:
    k = cfg.xattn_every
    n_groups = cfg.n_layers // k
    leftover = cfg.n_layers - n_groups * k    # plain layers at the end
    return n_groups, leftover


def init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    n_groups, leftover = _layout(cfg)
    ke, kg, kl, ku = jax.random.split(key, 4)

    def group_init(k):
        ks = jax.random.split(k, cfg.xattn_every)
        plain = jax.vmap(lambda kk: TF.init_layer(kk, cfg, dtype))(
            ks[:-1])
        return {"plain": plain,
                "xattn": init_xattn_layer(ks[-1], cfg, dtype)}

    params = {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "groups": jax.vmap(group_init)(jax.random.split(kg, n_groups)),
        "ln_f": TF._norm_init(cfg)(cfg.d_model, dtype),
    }
    if leftover:
        params["leftover"] = jax.vmap(
            lambda k: TF.init_layer(k, cfg, dtype))(
                jax.random.split(kl, leftover))
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(ku, cfg.vocab, cfg.d_model,
                                             dtype)
    return params


def _xattn_apply(cfg, mode, lp, x, vision_embeds, positions):
    x = TF._layer_fwd(cfg, mode, x, {k: lp[k] for k in
                                     ("ln_attn", "attn", "ln_mlp", "mlp")},
                      positions)
    h = TF.norm_apply(cfg, lp["ln_x"], x)
    a, _ = L.attention(lp["xattn"], h, _xattn_cfg(cfg), mode=mode,
                       xattn_kv=vision_embeds)
    gated = (jnp.tanh(lp["x_gate"]) * a.astype(jnp.float32)).astype(x.dtype)
    return constrain(x + gated, "act")


def forward(params: dict, tokens: Array, vision_embeds: Array,
            cfg: ArchConfig, *, mode: QuantMode = FP,
            remat: bool = True) -> Array:
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def group_body(x, gp):
        def plain_body(x, lp):
            return TF._layer_fwd(cfg, mode, x, lp, positions), None
        x, _ = jax.lax.scan(plain_body, x, gp["plain"])
        x = _xattn_apply(cfg, mode, gp["xattn"], x, vision_embeds, positions)
        return x, None

    if remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "leftover" in params:
        def plain_body(x, lp):
            return TF._layer_fwd(cfg, mode, x, lp, positions), None
        x, _ = jax.lax.scan(plain_body, x, params["leftover"])
    x = TF.norm_apply(cfg, params["ln_f"], x)
    head = params.get("unembed", params["embed"])
    return L.unembed(head, x)


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Self-attn KV cache + PRE-PROJECTED vision cross K/V (§Perf iter D:
    patch embeddings are static across decode, so each cross-attn layer's
    wk/wv run once at prime time, not per step).

    ``xlen`` (B,) is the per-row cross frontier (slot engine: each row
    masks its patch reads at its own primed count); it initializes to
    the full static patch count so un-primed batchwide flows behave
    exactly as before."""
    n_groups, leftover = _layout(cfg)
    k, v = L.init_kv_cache(batch, s_max, cfg.n_kv_heads, cfg.head_dim, dtype)
    xshape = (n_groups, batch, cfg.n_patches, cfg.n_kv_heads, cfg.head_dim)
    cache = {
        "k": jnp.zeros((n_groups, cfg.xattn_every) + k.shape, dtype),
        "v": jnp.zeros((n_groups, cfg.xattn_every) + k.shape, dtype),
        "xk": jnp.zeros(xshape, dtype),
        "xv": jnp.zeros(xshape, dtype),
        "xlen": jnp.full((batch,), cfg.n_patches, jnp.int32),
    }
    if leftover:
        cache["lo_k"] = jnp.zeros((leftover,) + k.shape, dtype)
        cache["lo_v"] = jnp.zeros((leftover,) + k.shape, dtype)
    return cache


def init_paged_cache(cfg: ArchConfig, num_slots: int, s_max: int,
                     block_size: int, num_blocks: int,
                     dtype=jnp.bfloat16) -> dict:
    """Paged self-attention KV: physical blocks replace the per-slot S
    axis in every self-KV leaf (grouped AND leftover — all layers share
    ONE block pool, indexed by the same per-slot table); cross K/V and
    ``xlen`` stay slot-resident exactly as in init_cache."""
    if s_max % block_size:
        raise ValueError(f"s_max={s_max} must tile into whole blocks of "
                         f"{block_size}")
    n_groups, leftover = _layout(cfg)
    blk = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    xshape = (n_groups, num_slots, cfg.n_patches, cfg.n_kv_heads,
              cfg.head_dim)
    cache = {
        "k": jnp.zeros((n_groups, cfg.xattn_every) + blk, dtype),
        "v": jnp.zeros((n_groups, cfg.xattn_every) + blk, dtype),
        "xk": jnp.zeros(xshape, dtype),
        "xv": jnp.zeros(xshape, dtype),
        "xlen": jnp.full((num_slots,), cfg.n_patches, jnp.int32),
        "block_tables": jnp.zeros((num_slots, s_max // block_size),
                                  jnp.int32),
    }
    if leftover:
        cache["lo_k"] = jnp.zeros((leftover,) + blk, dtype)
        cache["lo_v"] = jnp.zeros((leftover,) + blk, dtype)
    return cache


def paged_block_axes(cache: dict) -> dict:
    """Physical-block (NB) axis per PAGED leaf; cross K/V stays
    slot-resident (see init_paged_cache)."""
    axes = {"k": 2, "v": 2}
    if "lo_k" in cache:
        axes["lo_k"] = 1
        axes["lo_v"] = 1
    return axes


def cache_batch_axes(cache: dict) -> dict:
    """Batch (slot) axis per cache leaf: grouped self-KV stacks
    (group, layer-in-group) ahead of batch, cross K/V stacks the group
    axis only, leftover layers stack one layer axis, and ``xlen`` /
    the per-slot block table ARE batch-leading."""
    axes = {"k": 2, "v": 2, "xk": 1, "xv": 1, "xlen": 0,
            "block_tables": 0}
    if "lo_k" in cache:
        axes["lo_k"] = 1
        axes["lo_v"] = 1
    return axes


def _cross_kv(params, vision_embeds, cfg, *, mode=FP):
    """Pre-project every cross-attn group's K/V from patch embeddings
    (shared by the batchwide prime and the engine's per-slot prime)."""
    from repro.core.qlinear import linear
    b, npatch, d = vision_embeds.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim

    def project(_, gp):
        xp = gp["xattn"]["xattn"]
        xk = linear(xp["wk"], vision_embeds, mode=mode).reshape(
            b, npatch, kvh, hd)
        xv = linear(xp["wv"], vision_embeds, mode=mode).reshape(
            b, npatch, kvh, hd)
        return None, (xk, xv)

    _, (xk, xv) = jax.lax.scan(project, None, params["groups"])
    return xk, xv


def prime_cache(params, cache, vision_embeds, cfg, *, mode=FP):
    xk, xv = _cross_kv(params, vision_embeds, cfg, mode=mode)
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype),
                xlen=jnp.full((vision_embeds.shape[0],),
                              vision_embeds.shape[1], jnp.int32))


def prime_slot(params, source, n_valid, cfg, *, mode=FP):
    """Per-request prime for the slot engine: project ONE request's patch
    embeddings (``source`` (1, n_patches, D), padded to the static
    count) into the slot-resident cross K/V leaves plus the row's
    frontier ``n_valid`` (real patches; reads past it are masked)."""
    xk, xv = _cross_kv(params, source, cfg, mode=mode)
    return {"xk": xk, "xv": xv,
            "xlen": jnp.asarray(n_valid, jnp.int32).reshape(1)}


def decode_step(params: dict, tokens: Array, cache: dict, cache_index: Array,
                cfg: ArchConfig, *, mode: QuantMode = FP
                ) -> Tuple[Array, dict]:
    """One decode step.  ``cache_index`` is scalar () (lockstep batch) or
    (B,) per-row for the slot engine: RoPE positions, cache writes and
    self-attention masks become per-row, and every row's gated
    cross-attention masks patch reads at its OWN primed frontier
    (``cache["xlen"]``) — the per-slot primed cross-K/V contract."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    cache_index = jnp.asarray(cache_index)
    if cache_index.ndim:                    # (B,): per-slot positions
        positions = cache_index[:, None] + jnp.arange(s)[None, :]
    else:
        positions = cache_index + jnp.arange(s)[None, :]
    # per-row cross frontier only on the slot-engine (vector) path: the
    # lockstep batch primed batchwide attends exactly what it primed, so
    # masking is a no-op there and would only disable the TPU flash
    # cross-attention kernel
    xlen = cache["xlen"] if cache_index.ndim else None
    tables = cache.get("block_tables")      # (B, MB) int32: paged mode
    acfg = TF.attn_config(cfg)

    def one_layer(x, lp, ck, cv):
        h = TF.norm_apply(cfg, lp["ln_attn"], x)
        a, new_kv = L.attention(lp["attn"], h, acfg, mode=mode,
                                positions=positions, kv_cache=(ck, cv),
                                cache_index=cache_index,
                                block_tables=tables)
        x = x + a
        h = TF.norm_apply(cfg, lp["ln_mlp"], x)
        x = x + L.mlp(lp["mlp"], h, gated=cfg.gated_mlp,
                      activation=cfg.activation, mode=mode)
        return constrain(x, "act"), new_kv

    def group_body(x, inp):
        gp, ck, cv, xk, xv = inp     # ck: (xattn_every, B, S, KV, hd)
        def plain_body(x, lp_kv):
            lp, ck1, cv1 = lp_kv
            return one_layer(x, lp, ck1, cv1)
        x, (nk_p, nv_p) = jax.lax.scan(
            plain_body, x, (gp["plain"], ck[:-1], cv[:-1]))
        x, (nk_x, nv_x) = one_layer(x, {k: gp["xattn"][k] for k in
                                        ("ln_attn", "attn", "ln_mlp", "mlp")},
                                    ck[-1], cv[-1])
        h = TF.norm_apply(cfg, gp["xattn"]["ln_x"], x)
        a, _ = L.attention(gp["xattn"]["xattn"], h, _xattn_cfg(cfg),
                           mode=mode, xattn_precomputed=(xk, xv),
                           xattn_valid_len=xlen)
        gated = (jnp.tanh(gp["xattn"]["x_gate"])
                 * a.astype(jnp.float32)).astype(x.dtype)
        x = constrain(x + gated, "act")
        nk = jnp.concatenate([nk_p, nk_x[None]], axis=0)
        nv = jnp.concatenate([nv_p, nv_x[None]], axis=0)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        group_body, x, (params["groups"], cache["k"], cache["v"],
                        cache["xk"], cache["xv"]))
    if tables is not None:
        # paged: nk/nv are new-token entries (G, E, B, 1, KV, hd) —
        # scatter through each row's table into the physical pool
        new_cache = dict(
            cache,
            k=L.paged_append(cache["k"], nk, tables, cache_index,
                             block_axis=2),
            v=L.paged_append(cache["v"], nv, tables, cache_index,
                             block_axis=2))
    else:
        new_cache = dict(cache, k=nk, v=nv)
    if "leftover" in params:
        def plain_body(x, lp_kv):
            lp, ck1, cv1 = lp_kv
            return one_layer(x, lp, ck1, cv1)
        x, (lk, lv) = jax.lax.scan(
            plain_body, x, (params["leftover"], cache["lo_k"],
                            cache["lo_v"]))
        if tables is not None:
            new_cache["lo_k"] = L.paged_append(cache["lo_k"], lk, tables,
                                               cache_index, block_axis=1)
            new_cache["lo_v"] = L.paged_append(cache["lo_v"], lv, tables,
                                               cache_index, block_axis=1)
        else:
            new_cache["lo_k"] = lk
            new_cache["lo_v"] = lv
    x = TF.norm_apply(cfg, params["ln_f"], x)
    head = params.get("unembed", params["embed"])
    return L.unembed(head, x), new_cache
