"""RecurrentGemma / Griffin hybrid [arXiv:2402.19427].

Layer pattern: (rec, rec, attn) repeating — two RG-LRU recurrent blocks per
local-attention block (window 2048, MQA kv=1).  Every layer is
norm -> temporal-mixing -> residual; norm -> gated-MLP -> residual.

Recurrent block: two branches from x —
  a: linear(D->W) -> causal conv1d(4) -> RG-LRU
  b: linear(D->W) -> GeLU
merged a*b -> linear(W->D).

RG-LRU:  r_t = sigmoid(W_a x + b_a)        (recurrence gate)
         i_t = sigmoid(W_x x + b_x)        (input gate)
         log a_t = -c * softplus(Lambda) * r_t          (c = 8)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluate the linear recurrence with
``jax.lax.associative_scan`` (parallel prefix — O(log S) depth, TPU-native
replacement for the GPU kernel the Griffin paper uses); decode is the O(1)
update.  Fixed-size state -> long_500k runnable.  Gates/state in fp32 (the
32-bit-accumulator argument); projections quantize like all matmuls.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode, init_linear, linear
from repro.models import layers as L
from repro.models import transformer as TF
from repro.runtime.sharding import constrain

Array = jax.Array
_C = 8.0   # RG-LRU decay sharpness constant


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def init_rglru(key, width: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_a": init_linear(k1, width, width, bias=True, dtype=jnp.float32),
        "w_x": init_linear(k2, width, width, bias=True, dtype=jnp.float32),
        # Lambda init so a^c spans ~[0.9, 0.999] (paper's init range)
        "Lambda": jnp.linspace(-4.3, -1.5, width).astype(jnp.float32),
    }


def _rglru_gates(p: dict, x: Array, mode: QuantMode):
    r = jax.nn.sigmoid(linear(p["w_a"], x, mode=FP,
                              compute_dtype=jnp.float32))
    i = jax.nn.sigmoid(linear(p["w_x"], x, mode=FP,
                              compute_dtype=jnp.float32))
    log_a = -_C * jax.nn.softplus(p["Lambda"])[None, None] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, b


def rglru(p: dict, x: Array, *, mode: QuantMode = FP,
          state: Array = None) -> Tuple[Array, Array]:
    """x: (B, S, W).  Returns (y, last_state)."""
    a, b = _rglru_gates(p, x, mode)
    if state is None:
        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    else:
        # decode: single step (S == 1)
        h = a * state[:, None] + b
    return h.astype(x.dtype), h[:, -1]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_rec_block(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    w = cfg.rnn_width or cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "ln": L.init_rmsnorm(cfg.d_model, dtype),
        "w_in_a": init_linear(k1, cfg.d_model, w, bias=False, dtype=dtype),
        "w_in_b": init_linear(k2, cfg.d_model, w, bias=False, dtype=dtype),
        "conv_w": (jax.random.truncated_normal(
            k3, -2, 2, (cfg.conv_width, w), jnp.float32) * 0.3).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "lru": init_rglru(k4, w),
        "w_out": init_linear(k5, w, cfg.d_model, bias=False, dtype=dtype,
                             scale=w ** -0.5),
        "ln_mlp": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(jax.random.fold_in(key, 7), cfg.d_model, cfg.d_ff,
                          gated=cfg.gated_mlp, activation=cfg.activation,
                          dtype=dtype),
    }


def rec_block(p: dict, x: Array, cfg: ArchConfig, *, mode: QuantMode = FP,
              state: dict = None) -> Tuple[Array, dict]:
    from repro.models.ssm import _causal_conv
    h = L.rmsnorm(p["ln"], x)
    a = linear(p["w_in_a"], h, mode=mode)
    b = linear(p["w_in_b"], h, activation="gelu", mode=mode)
    conv_state = None if state is None else state["conv"]
    a, new_conv = _causal_conv(a, p["conv_w"], p["conv_b"], conv_state)
    lru_state = None if state is None else state["h"]
    a, new_h = rglru(p["lru"], a, mode=mode, state=lru_state)
    y = linear(p["w_out"], (a * b).astype(x.dtype), mode=mode)
    x = x + constrain(y, "act")
    h = L.rmsnorm(p["ln_mlp"], x)
    x = x + L.mlp(p["mlp"], h, gated=cfg.gated_mlp,
                  activation=cfg.activation, mode=mode)
    new_state = None if state is None else {"h": new_h, "conv": new_conv}
    return constrain(x, "act"), new_state


def _attn_cfg(cfg: ArchConfig) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=cfg.local_window)


def init_attn_block(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln": L.init_rmsnorm(cfg.d_model, dtype),
        "attn": L.init_attention(k1, _attn_cfg(cfg), dtype),
        "ln_mlp": L.init_rmsnorm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                          activation=cfg.activation, dtype=dtype),
    }


def attn_block(p: dict, x: Array, cfg: ArchConfig, *, mode: QuantMode = FP,
               positions=None, kv_cache=None, cache_index=None,
               valid_len=None) -> Tuple[Array, object]:
    acfg = _attn_cfg(cfg)
    h = L.rmsnorm(p["ln"], x)
    attn_out, new_kv = L.attention(
        p["attn"], h, acfg, mode=mode, positions=positions,
        kv_cache=kv_cache, cache_index=cache_index, valid_len=valid_len,
        positions_k=positions)
    x = x + attn_out
    h = L.rmsnorm(p["ln_mlp"], x)
    x = x + L.mlp(p["mlp"], h, gated=cfg.gated_mlp,
                  activation=cfg.activation, mode=mode)
    return constrain(x, "act"), new_kv


# ---------------------------------------------------------------------------
# full model: scan over (rec, rec, attn) groups + leftover rec blocks
# ---------------------------------------------------------------------------

def _layout(cfg: ArchConfig) -> Tuple[int, int]:
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    assert tuple(pat) == ("rec", "rec", "attn"), \
        "only the Griffin 2:1 pattern is implemented"
    n_groups = cfg.n_layers // 3
    leftover = cfg.n_layers - 3 * n_groups   # leading rec blocks
    assert leftover <= 2
    return n_groups, leftover


def init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    n_groups, leftover = _layout(cfg)
    ke, kg, kl = jax.random.split(key, 3)

    def group_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"rec0": init_rec_block(k1, cfg, dtype),
                "rec1": init_rec_block(k2, cfg, dtype),
                "attn": init_attn_block(k3, cfg, dtype)}

    params = {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "groups": jax.vmap(group_init)(jax.random.split(kg, n_groups)),
        "ln_f": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if leftover:
        params["leftover"] = jax.vmap(
            lambda k: init_rec_block(k, cfg, dtype))(
                jax.random.split(kl, leftover))
    return params


def forward(params: dict, tokens: Array, cfg: ArchConfig, *,
            mode: QuantMode = FP, remat: bool = True) -> Array:
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def group_body(x, gp):
        x, _ = rec_block(gp["rec0"], x, cfg, mode=mode)
        x, _ = rec_block(gp["rec1"], x, cfg, mode=mode)
        x, _ = attn_block(gp["attn"], x, cfg, mode=mode, positions=positions)
        return x, None

    if remat:
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if "leftover" in params:
        def rec_body(x, lp):
            out, _ = rec_block(lp, x, cfg, mode=mode)
            return out, None
        x, _ = jax.lax.scan(rec_body, x, params["leftover"])
    x = L.rmsnorm(params["ln_f"], x)
    return L.unembed(params["embed"], x)


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Fixed-size: RG-LRU states + conv tails + local-window ring KV."""
    n_groups, leftover = _layout(cfg)
    w = cfg.rnn_width or cfg.d_model
    win = min(cfg.local_window, s_max)
    cache = {
        "rnn_h": jnp.zeros((n_groups, 2, batch, w), jnp.float32),
        "conv": jnp.zeros((n_groups, 2, batch, cfg.conv_width - 1, w), dtype),
        "k": jnp.zeros((n_groups, batch, win, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((n_groups, batch, win, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
    }
    if leftover:
        cache["lo_rnn_h"] = jnp.zeros((leftover, batch, w), jnp.float32)
        cache["lo_conv"] = jnp.zeros(
            (leftover, batch, cfg.conv_width - 1, w), dtype)
    return cache


def mask_inactive_slots(old: dict, new: dict, active: Array) -> dict:
    """Freeze inactive slots' recurrent/conv state (slot engine contract).

    The local-attention KV ring is positional (masked reads via
    ``valid_len``) and needs no freeze, but the RG-LRU ``rnn_h`` and conv
    tails are not — inactive rows' state must stay bitwise untouched.
    ``active`` is (B,); batch axis is 2 for grouped state, 1 for leftover."""
    out = dict(new)
    out["rnn_h"] = jnp.where(active[None, None, :, None],
                             new["rnn_h"], old["rnn_h"])
    out["conv"] = jnp.where(active[None, None, :, None, None],
                            new["conv"], old["conv"])
    if "lo_rnn_h" in new:
        out["lo_rnn_h"] = jnp.where(active[None, :, None],
                                    new["lo_rnn_h"], old["lo_rnn_h"])
        out["lo_conv"] = jnp.where(active[None, :, None, None],
                                   new["lo_conv"], old["lo_conv"])
    return out


def cache_batch_axes(cache: dict) -> dict:
    """Batch axis per cache leaf (the slot engine slices slots with it).
    Grouped recurrent state stacks (group, block) ahead of batch."""
    axes = {"rnn_h": 2, "conv": 2, "k": 1, "v": 1}
    if "lo_rnn_h" in cache:
        axes["lo_rnn_h"] = 1
        axes["lo_conv"] = 1
    return axes


def decode_step(params: dict, tokens: Array, cache: dict, cache_index: Array,
                cfg: ArchConfig, *, mode: QuantMode = FP
                ) -> Tuple[Array, dict]:
    """One-token decode.  ``cache_index`` is scalar () (lockstep batch) or
    (B,) per-row for the slot engine: RoPE positions, ring write indices
    and window masks all become per-row, and — like the SSM — a row at
    position 0 has its recurrent/conv state zeroed before the update (the
    reset-at-zero scrub that makes slot reuse safe)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    ci = jnp.asarray(cache_index)
    if ci.ndim:                             # (B,): per-slot positions
        positions = ci[:, None] + jnp.arange(s)[None, :]
    else:
        positions = ci + jnp.arange(s)[None, :]
    win = cache["k"].shape[2]
    write_idx = ci % win
    valid_len = jnp.minimum(ci + s, win)
    fresh = jnp.broadcast_to(ci == 0, (b,))
    cache = dict(
        cache,
        rnn_h=jnp.where(fresh[None, None, :, None],
                        jnp.zeros_like(cache["rnn_h"]), cache["rnn_h"]),
        conv=jnp.where(fresh[None, None, :, None, None],
                       jnp.zeros_like(cache["conv"]), cache["conv"]))
    if "lo_rnn_h" in cache:
        cache = dict(
            cache,
            lo_rnn_h=jnp.where(fresh[None, :, None],
                               jnp.zeros_like(cache["lo_rnn_h"]),
                               cache["lo_rnn_h"]),
            lo_conv=jnp.where(fresh[None, :, None, None],
                              jnp.zeros_like(cache["lo_conv"]),
                              cache["lo_conv"]))

    def group_body(x, inp):
        gp, h2, conv2, ck, cv = inp
        x, st0 = rec_block(gp["rec0"], x, cfg, mode=mode,
                           state={"h": h2[0], "conv": conv2[0]})
        x, st1 = rec_block(gp["rec1"], x, cfg, mode=mode,
                           state={"h": h2[1], "conv": conv2[1]})
        x, new_kv = attn_block(gp["attn"], x, cfg, mode=mode,
                               positions=positions, kv_cache=(ck, cv),
                               cache_index=write_idx, valid_len=valid_len)
        new_h = jnp.stack([st0["h"], st1["h"]])
        new_conv = jnp.stack([st0["conv"], st1["conv"]])
        return x, (new_h, new_conv, new_kv[0], new_kv[1])

    x, (nh, nc, nk, nv) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["rnn_h"], cache["conv"],
         cache["k"], cache["v"]))
    new_cache = dict(cache, rnn_h=nh, conv=nc, k=nk, v=nv)

    if "leftover" in params:
        def rec_body(x, inp):
            lp, h, conv = inp
            x, st = rec_block(lp, x, cfg, mode=mode,
                              state={"h": h, "conv": conv})
            return x, (st["h"], st["conv"])
        x, (lh, lc) = jax.lax.scan(
            rec_body, x,
            (params["leftover"], cache["lo_rnn_h"], cache["lo_conv"]))
        new_cache["lo_rnn_h"] = lh
        new_cache["lo_conv"] = lc

    x = L.rmsnorm(params["ln_f"], x)
    return L.unembed(params["embed"], x), new_cache
