"""Shared model components: norms, RoPE, GQA attention, MLPs, embeddings.

Conventions
-----------
- Functional: ``init_*`` returns a param dict; apply fns are pure.
- Per-layer params are stacked on a leading L axis by the model builders and
  consumed through ``lax.scan`` (keeps HLO size and compile time independent
  of depth — essential for the 100-layer dry-run cells).
- Every matmul routes through :func:`repro.core.qlinear.linear`, so
  post-training int8 quantization (the paper's technique) switches the whole
  model without touching model code.
- Sharding is expressed with ``with_sharding_constraint`` through
  :func:`repro.runtime.sharding.constrain` (a no-op outside a mesh), using
  logical axis names resolved by the active sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.qlinear import QuantMode, FP, init_linear, linear
from repro.core.quant import QTensor
from repro.runtime.sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                            # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, chunked for long sequences)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding-window size (None = full)
    qkv_bias: bool = False           # qwen1.5-style QKV bias
    causal: bool = True
    use_rope: bool = True
    q_block: int = 512               # chunked-attention query block


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": init_linear(kq, d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(kk, d, kvh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(kv, d, kvh * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ko, h * hd, d, bias=False, dtype=dtype,
                          scale=(h * hd) ** -0.5),
    }


def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each KV group."""
    b, s, kvh, hd = k.shape
    if kvh == n_heads:
        return k
    return jnp.repeat(k, n_heads // kvh, axis=2)


def _cache_write(c: Array, new: Array, idx: Array) -> Array:
    """Write ``new`` (B, s, KV, hd) into ``c`` (B, S_slots, KV, hd) at
    sequence position ``idx`` — scalar () for lockstep decode, or (B,) for
    the slot engine, where every row scatters at its own position."""
    idx = jnp.asarray(idx)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice(c, new, (0, idx, 0, 0))
    return jax.vmap(
        lambda cb, nb, ib: jax.lax.dynamic_update_slice(cb, nb, (ib, 0, 0))
    )(c, new, idx)


def paged_gather(c: Array, block_tables: Array) -> Array:
    """Gather physical KV blocks (NB, bs, ...) through per-row tables
    (B, MB) into the contiguous (B, MB*bs, ...) logical layout the dense
    decode path uses — identical bytes in, identical einsums out, so the
    paged path stays bit-for-bit with the contiguous reference."""
    g = c[block_tables]                       # (B, MB, bs, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_append(c: Array, new: Array, block_tables: Array, idx: Array, *,
                 block_axis: int) -> Array:
    """Scatter each row's new-token entry (..., B, 1, KV, hd) into the
    physical block pool (..., NB, bs, KV, hd) through its table at
    logical position ``idx`` (() or (B,)).  ``block_axis`` is the NB axis
    of ``c`` (the batch axis of ``new``).  Rows whose table entry is the
    reserved trash block 0 write there harmlessly — trash is never read
    because attention masks past each row's frontier."""
    blk = c.shape[block_axis + 1]
    batch = new.shape[block_axis]
    idx = jnp.asarray(idx)
    if idx.ndim == 0:
        idx = jnp.full((batch,), idx, jnp.int32)
    phys = block_tables[jnp.arange(batch), idx // blk]    # (B,)
    off = idx % blk                                       # (B,)
    pre = (slice(None),) * block_axis
    return c.at[pre + (phys, off)].set(new[pre + (slice(None), 0)])


def _chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                       window: Optional[int], q_block: int,
                       q_offset: int = 0,
                       kv_valid_len: Optional[Array] = None) -> Array:
    """Memory-bounded attention: scan over query blocks, masked scores.

    q: (B, Sq, H, hd); k, v: (B, Sk, H, hd).  Keeps the live score tensor at
    (B, H, q_block, Sk) — the JAX-level analogue of streaming activations
    through the Unified Buffer instead of materializing the full S^2 matrix.

    Note (§Perf, refuted experiment): a pure-JAX online-softmax variant
    (nested scan over KV blocks carrying m/l/acc) measured WORSE on the
    dry-run byte model (+17-24% memory term) — the scan-carried state and
    per-pair remat replay outweigh the probs it avoids.  The fused
    `kernels/flash_attention.py` (used on TPU) gets the win without the
    JAX-level state traffic; this path stays the CPU/dry-run baseline.
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    blk = min(q_block, sq)
    pad = (-sq) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = q.shape[1] // blk
    qb = q.reshape(b, nblk, blk, h, hd).transpose(1, 0, 3, 2, 4)  # (n,B,H,blk,hd)
    kt = k.transpose(0, 2, 3, 1)   # (B, H, hd, Sk)
    vt = v.transpose(0, 2, 1, 3)   # (B, H, Sk, hd)
    kpos = jnp.arange(sk)

    def one_block(carry, inp):
        qi, idx = inp
        scores = jnp.einsum("bhqd,bhdk->bhqk", qi.astype(jnp.float32),
                            kt.astype(jnp.float32)) * scale
        qpos = q_offset + idx * blk + jnp.arange(blk)
        mask = jnp.ones((blk, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        if kv_valid_len is not None:
            # per-row KV frontier (slot engine: each row's primed source
            # has its own valid length)
            vmask = kpos[None, :] < kv_valid_len.reshape(-1, 1)   # (B, Sk)
            scores = jnp.where(vmask[:, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bhkd->bhqd", probs, vt.astype(jnp.float32))
        return carry, out.astype(q.dtype)

    # flash-attention memory discipline: recompute scores/probs per block in
    # the backward instead of saving (B, H, blk, Sk) f32 per block.
    one_block = jax.checkpoint(
        one_block, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(one_block, None, (qb, jnp.arange(nblk)))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, nblk * blk, h, hd)
    return out[:, :sq]


def attention(p: dict, x: Array, cfg: AttnConfig, *,
              mode: QuantMode = FP,
              positions: Optional[Array] = None,
              kv_cache: Optional[Tuple[Array, Array]] = None,
              cache_index: Optional[Array] = None,
              valid_len: Optional[Array] = None,
              positions_k: Optional[Array] = None,
              xattn_kv: Optional[Array] = None,
              xattn_precomputed: Optional[Tuple[Array, Array]] = None,
              xattn_valid_len: Optional[Array] = None,
              append_only: bool = False,
              block_tables: Optional[Array] = None,
              ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """GQA attention with three modes:

    - training / prefill: kv_cache=None -> chunked causal self-attention.
    - decode: kv_cache=(K, V) of shape (B, S_slots, KV, hd); cache_index =
      write slot; valid_len = number of valid slots; x is (B, 1, D).
      ``cache_index`` / ``valid_len`` may be scalars () — the whole batch
      advances in lockstep — or vectors (B,) for the slot-based serving
      engine, where every batch row is an independent request at its own
      sequence position (writes become per-row scatters, masks and RoPE
      positions per-row).
      Sliding-window archs use a ring buffer (S_slots = window): RoPE is
      applied at absolute positions before caching, so slot order does not
      affect scores, and masking is just `slot < valid_len`.
    - cross-attention: xattn_kv = encoder/vision states (B, S_src, D);
      non-causal over the source (cache unused; K/V recomputed — static
      source states make this a pure matmul, MXU-friendly).
      ``xattn_precomputed`` = (K, V) projected once at prime time (the
      slot engine's per-slot primed cross operand); ``xattn_valid_len``
      () or (B,) masks each row's source reads at its own primed length,
      so a slot row holding a shorter source (or a previous tenant's
      stale tail) contributes nothing past the frontier.

    ``block_tables`` (B, MB) int32 switches decode to the paged KV cache:
    ``kv_cache`` leaves are physical blocks (NB, bs, KV, hd) and each
    row's logical position p lives at block ``table[b, p // bs]``, offset
    ``p % bs``.  The einsum path gathers the blocks into the SAME
    contiguous layout as above and writes the new token into the gathered
    view, so the math (and its rounding) is bit-identical to the
    contiguous non-append path; ``new_cache`` returns just the new-token
    entries for the caller to scatter through the table outside the layer
    scan (see :func:`paged_append`).
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x, mode=mode).reshape(b, s, h, hd)
    if xattn_precomputed is not None:
        # §Perf iteration D: source K/V were projected ONCE at prime time
        # (encoder frames / vision patches are static across decode steps)
        k, v = xattn_precomputed
        xattn_kv = k    # flags the non-causal source-attention path below
    else:
        kv_src = xattn_kv if xattn_kv is not None else x
        k = linear(p["wk"], kv_src, mode=mode).reshape(
            b, kv_src.shape[1], kvh, hd)
        v = linear(p["wv"], kv_src, mode=mode).reshape(
            b, kv_src.shape[1], kvh, hd)

    if positions is None:
        positions = jnp.arange(s)[None, :]
    if cfg.use_rope and xattn_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        # k is rotated at its *absolute* position before caching, so ring
        # storage order does not affect the scores.
        kpos = positions if positions_k is None else positions_k
        k = apply_rope(k, kpos, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        # GQA-aware decode: contract against the cache in its native
        # (B, S, KV, hd) layout — materializing the KV->H repeat would cost
        # G x the cache traffic and force GSPMD to reshard the whole cache
        # (measured: the dominant collective term of the decode baseline).
        q = constrain(q, "act_heads_decode")
        quantized = len(kv_cache) == 4          # (k, v, k_scale, v_scale)
        paged = block_tables is not None

        def q8(t):                              # (B, s, KV, hd) -> int8
            tf = t.astype(jnp.float32)
            amax = jnp.maximum(jnp.max(jnp.abs(tf), axis=-1,
                                       keepdims=True), 1e-6)
            sc = amax / 127.0
            return (jnp.round(tf / sc).astype(jnp.int8),
                    sc.astype(jnp.float32))

        if quantized:
            # int8 cache with per-(token, head) scales — the paper's 8-bit
            # discipline applied to the KV cache (halves cache HBM traffic
            # and footprint vs bf16; §Perf iteration C1).
            ck, cv, cks, cvs = kv_cache
            kq, ks = q8(k)
            vq, vs = q8(v)
            if paged:
                # Paged: gather physical blocks into the contiguous layout
                # and write the new token into the gathered VIEW — the
                # einsum below then sees byte-identical inputs to the
                # contiguous non-append path (bit parity).  Only the
                # new-token entries return; the caller scatters them
                # through the table post-scan.
                pck, pcv, pcks, pcvs = ck, cv, cks, cvs
                ck = _cache_write(paged_gather(pck, block_tables), kq,
                                  cache_index)
                cv = _cache_write(paged_gather(pcv, block_tables), vq,
                                  cache_index)
                cks = _cache_write(paged_gather(pcks, block_tables), ks,
                                   cache_index)
                cvs = _cache_write(paged_gather(pcvs, block_tables), vs,
                                   cache_index)
                new_cache = (kq, vq, ks, vs)
            elif append_only:
                # §Perf iteration A4/C3: do NOT rewrite the cache slice
                # inside the layer scan (that costs a full slice write+read
                # per layer per step); return just the new token's entry —
                # the caller appends once, outside the scan.
                new_cache = (kq, vq, ks, vs)
            else:
                ck = _cache_write(ck, kq, cache_index)
                cv = _cache_write(cv, vq, cache_index)
                cks = _cache_write(cks, ks, cache_index)
                cvs = _cache_write(cvs, vs, cache_index)
                ck = constrain(ck, "kv_cache")
                cv = constrain(cv, "kv_cache")
                cks = constrain(cks, "kv_cache")
                cvs = constrain(cvs, "kv_cache")
                new_cache = (ck, cv, cks, cvs)
            k_self, v_self = kq.astype(jnp.float32) * ks, \
                vq.astype(jnp.float32) * vs
        else:
            ck, cv = kv_cache                   # (B, S_slots, KV, hd)
            if paged:
                pck, pcv = ck, cv
                ck = _cache_write(paged_gather(pck, block_tables),
                                  k.astype(pck.dtype), cache_index)
                cv = _cache_write(paged_gather(pcv, block_tables),
                                  v.astype(pcv.dtype), cache_index)
                new_cache = (k.astype(pck.dtype), v.astype(pcv.dtype))
            elif append_only:
                new_cache = (k.astype(ck.dtype), v.astype(cv.dtype))
            else:
                ck = _cache_write(ck, k.astype(ck.dtype), cache_index)
                cv = _cache_write(cv, v.astype(cv.dtype), cache_index)
                ck = constrain(ck, "kv_cache")
                cv = constrain(cv, "kv_cache")
                new_cache = (ck, cv)
            k_self, v_self = k, v
        smax = ck.shape[1]
        g = h // kvh                            # heads per KV group
        if valid_len is None:
            valid_len = cache_index + s
        if (quantized and s == 1 and jax.default_backend() == "tpu"):
            # Fused Pallas decode attention: streams the int8 cache and
            # dequantizes tile-by-tile in VMEM (per-token scales folded
            # into score/prob columns), removing the decode path's
            # dominant memory term — the materialized dequantized cache.
            # Append path: the cache holds tokens < cache_index and the
            # current token's k/v ride along as an extra kernel operand,
            # so the fused kernel now serves ALL quantized decode, not
            # only the in-scan-update (non-append) variant.
            from repro.kernels import ops as kops
            if paged:
                # physical blocks stream through the per-row table inside
                # the kernel (scalar-prefetch grid); the cache holds tokens
                # < cache_index, the current token's k/v ride along as the
                # append column.  The gathered view above is dead code on
                # this branch and gets DCE'd.
                out = kops.decode_attention(
                    q.reshape(b, kvh, g, hd), pck, pcv, pcks, pcvs,
                    cache_index, block_tables=block_tables,
                    k_new=k_self, v_new=v_self, out_dtype=jnp.float32)
            elif append_only:
                out = kops.decode_attention(
                    q.reshape(b, kvh, g, hd), ck, cv, cks, cvs,
                    cache_index, k_new=k_self, v_new=v_self,
                    out_dtype=jnp.float32)
            else:
                out = kops.decode_attention(
                    q.reshape(b, kvh, g, hd), ck, cv, cks, cvs, valid_len,
                    out_dtype=jnp.float32)
            out = out.astype(x.dtype).reshape(b, s, h, hd)
            out = constrain(out, "act_heads")
            out = linear(p["wo"], out.reshape(b, s, h * hd), mode=mode)
            return constrain(out, "act"), new_cache
        q5 = q.reshape(b, s, kvh, g, hd)
        scale = hd ** -0.5
        # bf16-native contractions with f32 accumulate; per-token dequant
        # scales are independent of the contracted hd axis, so they fold
        # into the scores/probs instead of materializing a dequantized
        # cache copy (§Perf iteration A3/C2).
        scores = jnp.einsum("bqkgd,bskd->bkgqs",
                            q5.astype(jnp.bfloat16),
                            ck.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32) * scale
        if quantized:
            scores = scores * cks[..., 0].transpose(0, 2, 1)[:, :, None,
                                                             None, :]
        kpos_idx = jnp.arange(smax)
        if append_only:
            # cache holds tokens < cache_index; the current token's k/v are
            # handled as an extra score column below.
            bound = cache_index
        else:
            bound = valid_len
        # (1, S) lockstep, or (B, S) when the slot engine passes per-row
        # indices — every request masks at its own sequence frontier.
        valid = kpos_idx[None, :] < jnp.asarray(bound).reshape(-1, 1)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        if append_only:
            s_self = jnp.einsum("bqkgd,btkd->bkgqt",
                                q5.astype(jnp.float32),
                                k_self.astype(jnp.float32)) * scale
            scores = jnp.concatenate([scores, s_self], axis=-1)
        probs = jax.nn.softmax(scores, axis=-1)
        if append_only:
            probs, p_self = probs[..., :smax], probs[..., smax:]
        if quantized:
            probs = probs * cvs[..., 0].transpose(0, 2, 1)[:, :, None,
                                                           None, :]
        out = jnp.einsum("bkgqs,bskd->bqkgd",
                         probs.astype(jnp.bfloat16),
                         cv.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        if append_only:
            out = out + jnp.einsum("bkgqt,btkd->bqkgd",
                                   p_self.astype(jnp.float32),
                                   v_self.astype(jnp.float32))
        out = out.astype(x.dtype).reshape(b, s, h, hd)
    else:
        q = constrain(q, "act_heads")  # (B, S, H, hd): H over model axis
        kfull = _expand_kv(k, h)
        vfull = _expand_kv(v, h)
        causal = cfg.causal and xattn_kv is None
        window = cfg.window if xattn_kv is None else None
        if jax.default_backend() == "tpu" and xattn_valid_len is None:
            # Pallas fused flash kernel: probs never leave VMEM (the
            # Unified-Buffer discipline); HBM traffic = Q+K+V+O.  The
            # kernel carries no per-row KV frontier, so a primed source
            # with per-row valid lengths takes the masked chunked path.
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, kfull, vfull, causal=causal,
                                       window=window)
        else:
            # pure-JAX chunked path: identical math (tests assert so),
            # used on CPU and in the dry-run.
            out = _chunked_attention(q, kfull, vfull, causal=causal,
                                     window=window, q_block=cfg.q_block,
                                     kv_valid_len=xattn_valid_len)
    out = constrain(out, "act_heads")
    out = linear(p["wo"], out.reshape(b, s, h * hd), mode=mode)
    return constrain(out, "act"), new_cache


def init_kv_cache(batch: int, s_max: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Tuple[Array, Array]:
    shape = (batch, s_max, n_kv, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, *, gated: bool = True,
             activation: str = "silu", bias: bool = False,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": init_linear(k1, d_model, d_ff, bias=bias, dtype=dtype),
         "w_down": init_linear(k2, d_ff, d_model, bias=bias, dtype=dtype,
                               scale=d_ff ** -0.5)}
    if gated:
        p["w_gate"] = init_linear(k3, d_model, d_ff, bias=bias, dtype=dtype)
    return p


def mlp(p: dict, x: Array, *, gated: bool, activation: str,
        mode: QuantMode = FP) -> Array:
    if gated:
        g = linear(p["w_gate"], x, activation=activation, mode=mode)
        u = linear(p["w_up"], x, mode=mode)
        h = constrain(g * u, "act_ff")
    else:
        h = linear(p["w_up"], x, activation=activation, mode=mode)
        h = constrain(h, "act_ff")
    return constrain(linear(p["w_down"], h, mode=mode), "act")


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * d_model ** -0.5).astype(dtype)}


def embed(p: dict, tokens: Array, compute_dtype=jnp.bfloat16) -> Array:
    table = p["table"]
    if isinstance(table, QTensor):
        # per-row scales: gather int8 rows, dequantize the gathered slice
        rows = table.values[tokens].astype(compute_dtype)
        scale = table.scale.reshape(-1)[tokens][..., None]
        return constrain(rows * scale.astype(compute_dtype), "act")
    return constrain(table.astype(compute_dtype)[tokens], "act")


def unembed(p: dict, x: Array, compute_dtype=jnp.bfloat16) -> Array:
    """(Tied) LM head: logits = x @ table.T, fp32 accumulate.  Quantized
    tables have per-row scales, folded per output column of the head."""
    table = p["table"]
    if isinstance(table, QTensor):
        logits = jnp.einsum("bsd,vd->bsv", x.astype(compute_dtype),
                            table.values.astype(compute_dtype),
                            preferred_element_type=jnp.float32)
        logits = logits * table.scale.reshape(1, 1, -1)
    else:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(compute_dtype),
                            table.astype(compute_dtype),
                            preferred_element_type=jnp.float32)
    return constrain(logits, "logits")
