"""Mixture-of-Experts LM (qwen2-moe-a2.7b, mixtral-8x22b).

Decoder layer = GQA attention + MoE FFN.  The MoE FFN uses capacity-based
top-k routing with a grouped matmul formulation:

  router (fp32, tiny — kept unquantized, mirroring the paper keeping control
  logic out of the quantized datapath) -> top-k experts per token ->
  scatter tokens into an (E, C, D) dispatch buffer (C = capacity) ->
  one batched einsum per FFN matmul over all experts -> weighted combine.

This keeps HLO FLOPs proportional to *active* experts (top_k/E of dense),
which is what MODEL_FLOPS=6*N_active*D in the roofline expects, and the
dispatch/combine are pure data movement (gather/scatter), not matmul.

Sharding: expert weights (E, D, F) are TP-sharded on F over "model" and
FSDP on D over "data" — identical collective structure to the dense FFN.
True expert-parallel placement (E over "model") is a rule-set swap; the
default avoids it because 60 and 8 don't divide the 16-wide model axis
(DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode, init_linear, linear
from repro.core.quant import QTensor
from repro.models import layers as L
from repro.models import transformer as TF
from repro.runtime.sharding import constrain

Array = jax.Array


def _expert_matmul(w, x_ecd: Array, mode: QuantMode) -> Array:
    """(E, C, D) x (E, D, F) -> (E, C, F); QTensor-aware."""
    if isinstance(w, QTensor):
        wf = w.values.astype(jnp.bfloat16) * w.scale.astype(jnp.bfloat16)
    else:
        wf = w.astype(jnp.bfloat16)
    return jnp.einsum("ecd,edf->ecf", x_ecd.astype(jnp.bfloat16), wf,
                      preferred_element_type=jnp.float32
                      ).astype(x_ecd.dtype)


def init_moe_ffn(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    std = d ** -0.5
    def w(k, shape, s=std):
        return (jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
                * s).astype(dtype)
    p = {
        "router": init_linear(kr, d, e, bias=False, dtype=jnp.float32),
        "experts": {
            "w_gate": w(kg, (e, d, f)),
            "w_up": w(ku, (e, d, f)),
            "w_down": w(kd, (e, f, d), s=f ** -0.5),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(
            ks, d, f * cfg.n_shared_experts, gated=cfg.gated_mlp,
            activation=cfg.activation, dtype=dtype)
    return p


def moe_ffn(p: dict, x: Array, cfg: ArchConfig, *,
            mode: QuantMode = FP) -> Array:
    """x: (B, S, D) -> (B, S, D).

    Dispatch is LOCAL per batch row (vmapped): each row routes its own S
    tokens into an (E, C_row, D) buffer, so the scatter/cumsum never
    crosses the dp sharding of the batch.  The original global-scatter
    formulation made GSPMD all-reduce the full (E, C, D) dispatch buffer
    per layer — measured as the dominant collective term of the MoE train
    baseline (§Perf iteration B1: 23.0 s -> see EXPERIMENTS.md).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(s * k / e * cfg.capacity_factor))

    def route_row(xt):                                    # (S, D)
        logits = linear(p["router"], xt.astype(jnp.float32), mode=FP,
                        compute_dtype=jnp.float32)        # (S, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)            # (S, k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        flat_e = top_e.reshape(-1)                        # (S*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = my_pos < cap
        tok_idx = jnp.repeat(jnp.arange(s), k)
        safe_pos = jnp.where(keep, my_pos, 0)
        disp = jnp.zeros((e, cap, d), x.dtype)
        disp = disp.at[flat_e, safe_pos].add(
            jnp.where(keep[:, None], xt[tok_idx], 0.0))
        return disp, flat_e, safe_pos, keep, top_p

    xt = x                                                 # (B, S, D)
    disp, flat_e, safe_pos, keep, top_p = jax.vmap(route_row)(xt)
    disp = constrain(disp, "moe_disp")                     # (B, E, C, D)

    # expert FFNs as grouped matmuls over all rows at once.  bf16 operands
    # on TPU (MXU-native); f32 on CPU, whose dot runtime lacks the batched
    # BF16xBF16=F32 thunk.
    cdt = jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32

    def emm(w, t):                                         # (B,E,C,D)x(E,D,F)
        if isinstance(w, QTensor):
            wf = w.values.astype(cdt) * w.scale.astype(cdt)
        else:
            wf = w.astype(cdt)
        return jnp.einsum("becd,edf->becf", t.astype(cdt), wf,
                          preferred_element_type=jnp.float32
                          ).astype(t.dtype)

    g = emm(p["experts"]["w_gate"], disp)
    if cfg.activation == "silu":
        g = g * jax.nn.sigmoid(g)
    else:
        g = jax.nn.gelu(g)
    u = emm(p["experts"]["w_up"], disp)
    h = constrain(g * u, "moe_disp")
    out_becd = emm(p["experts"]["w_down"], h)
    out_becd = constrain(out_becd, "moe_disp")

    # combine: per-row gather back, weight by router prob
    def combine_row(o, fe, sp, kp, tp):
        gathered = o[fe, sp]                               # (S*k, D)
        w = (tp.reshape(-1, 1) * kp[:, None]).astype(gathered.dtype)
        return jnp.sum((gathered * w).reshape(s, k, d), axis=1)

    out = jax.vmap(combine_row)(out_becd, flat_e, safe_pos, keep, top_p)

    if "shared" in p:
        out = out + L.mlp(p["shared"], xt, gated=cfg.gated_mlp,
                          activation=cfg.activation, mode=mode)
    return constrain(out, "act")


def aux_load_balance_loss(p: dict, x: Array, cfg: ArchConfig) -> Array:
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    b, s, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    logits = linear(p["router"], xt, mode=FP, compute_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.n_experts), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * mean_p)


# ---------------------------------------------------------------------------
# full model: attention from transformer.py + MoE FFN
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": TF._norm_init(cfg)(cfg.d_model, dtype),
        "attn": L.init_attention(k1, TF.attn_config(cfg), dtype),
        "ln_mlp": TF._norm_init(cfg)(cfg.d_model, dtype),
        "moe": init_moe_ffn(k2, cfg, dtype),
    }


def init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stacked,
        "ln_f": TF._norm_init(cfg)(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(ku, cfg.vocab, cfg.d_model,
                                             dtype)
    return params


# truncated-layer self-draft: the moe param tree has the same
# {embed, layers (vmap-stacked), ln_f[, unembed]} shape as the dense one,
# so the slice-the-stack view applies verbatim (expert weights ride the
# same leading layer axis)
draft_params = TF.draft_params


def forward(params: dict, tokens: Array, cfg: ArchConfig, *,
            mode: QuantMode = FP, remat: bool = True) -> Array:
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    acfg = TF.attn_config(cfg)

    def body(x, lp):
        h = TF.norm_apply(cfg, lp["ln_attn"], x)
        attn_out, _ = L.attention(lp["attn"], h, acfg, mode=mode,
                                  positions=positions)
        x = x + attn_out
        h = TF.norm_apply(cfg, lp["ln_mlp"], x)
        x = x + moe_ffn(lp["moe"], h, cfg, mode=mode)
        return constrain(x, "act"), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = TF.norm_apply(cfg, params["ln_f"], x)
    head = params.get("unembed", params["embed"])
    return L.unembed(head, x)


init_cache = TF.init_cache
init_paged_cache = TF.init_paged_cache
paged_block_axes = TF.paged_block_axes


def decode_step(params: dict, tokens: Array, cache: dict, cache_index: Array,
                cfg: ArchConfig, *, mode: QuantMode = FP
                ) -> Tuple[Array, dict]:
    """One decode step; ``cache_index`` scalar () (lockstep) or (B,)
    per-row for the slot engine, exactly as in the dense family.  Expert
    routing needs no extra per-row plumbing: dispatch/combine are already
    vmapped per batch row, so each slot routes its own token against its
    own position-independent router state."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    cache_index = jnp.asarray(cache_index)
    if cache_index.ndim:                    # (B,): per-slot positions
        positions = cache_index[:, None] + jnp.arange(s)[None, :]
    else:
        positions = cache_index + jnp.arange(s)[None, :]
    acfg = TF.attn_config(cfg)
    tables = cache.get("block_tables")      # (B, MB) int32: paged mode
    if tables is not None:
        s_alloc = tables.shape[1] * cache["k"].shape[2]   # MB * bs
    else:
        s_alloc = cache["k"].shape[2]
    write_idx = cache_index % s_alloc if cfg.window else cache_index
    valid_len = jnp.minimum(cache_index + s, s_alloc)
    quant = "k_scale" in cache
    append = (tables is None and cfg.window is None
              and cfg.n_kv_heads >= 16)     # see TF.decode_step

    def body(x, lp_and_cache):
        if quant:
            lp, ck, cv, cks, cvs = lp_and_cache
            kv = (ck, cv, cks, cvs)
        else:
            lp, ck, cv = lp_and_cache
            kv = (ck, cv)
        h = TF.norm_apply(cfg, lp["ln_attn"], x)
        attn_out, new_kv = L.attention(
            lp["attn"], h, acfg, mode=mode, positions=positions,
            kv_cache=kv, cache_index=write_idx,
            valid_len=valid_len, positions_k=positions,
            append_only=append, block_tables=tables)
        x = x + attn_out
        h = TF.norm_apply(cfg, lp["ln_mlp"], x)
        x = x + moe_ffn(lp["moe"], h, cfg, mode=mode)
        return constrain(x, "act"), new_kv

    w = TF._stacked_cache_write            # scalar () or per-row (B,) idx
    if quant:
        xs = (params["layers"], cache["k"], cache["v"],
              cache["k_scale"], cache["v_scale"])
        x, (nk, nv, nks, nvs) = jax.lax.scan(body, x, xs)
        if tables is not None:
            new_cache = dict(cache)
            for key, new in (("k", nk), ("v", nv),
                             ("k_scale", nks), ("v_scale", nvs)):
                new_cache[key] = L.paged_append(cache[key], new, tables,
                                                write_idx, block_axis=1)
        elif append:
            new_cache = {
                "k": w(cache["k"], nk, write_idx),
                "v": w(cache["v"], nv, write_idx),
                "k_scale": w(cache["k_scale"], nks, write_idx),
                "v_scale": w(cache["v_scale"], nvs, write_idx)}
        else:
            new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    else:
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        if tables is not None:
            new_cache = dict(cache)
            new_cache["k"] = L.paged_append(cache["k"], nk, tables,
                                            write_idx, block_axis=1)
            new_cache["v"] = L.paged_append(cache["v"], nv, tables,
                                            write_idx, block_axis=1)
        elif append:
            new_cache = {"k": w(cache["k"], nk, write_idx),
                         "v": w(cache["v"], nv, write_idx)}
        else:
            new_cache = {"k": nk, "v": nv}
    x = TF.norm_apply(cfg, params["ln_f"], x)
    head = params.get("unembed", params["embed"])
    return L.unembed(head, x), new_cache
