"""Dense decoder-only LM (starcoder2 / mistral-nemo / internlm2 / qwen1.5).

Structure: embedding -> lax.scan over stacked decoder layers -> final norm ->
(tied) unembed.  One decoder layer = norm -> GQA attention -> residual ->
norm -> MLP -> residual.  Quantization mode threads through every matmul.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode
from repro.models import layers as L
from repro.runtime.sharding import constrain

Array = jax.Array


def attn_config(cfg: ArchConfig, *, window=None) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        window=window if window is not None else cfg.window,
        qkv_bias=cfg.qkv_bias)


def _norm_init(cfg: ArchConfig):
    return (L.init_layernorm if cfg.norm == "layernorm"
            else L.init_rmsnorm)


def norm_apply(cfg: ArchConfig, p, x):
    return (L.layernorm if cfg.norm == "layernorm" else L.rmsnorm)(p, x)


def init_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": _norm_init(cfg)(cfg.d_model, dtype),
        "attn": L.init_attention(k1, attn_config(cfg), dtype),
        "ln_mlp": _norm_init(cfg)(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                          activation=cfg.activation, dtype=dtype),
    }


def _strip_meta(p):
    return {k: v for k, v in p.items() if k != "_meta"}


def init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg, dtype))(layer_keys)
    params = {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stacked,
        "ln_f": _norm_init(cfg)(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(ku, cfg.vocab, cfg.d_model,
                                             dtype)
    return params


def draft_params(params: dict, n_layers: int) -> dict:
    """Truncated-layer *self-draft* view: the first ``n_layers`` of the
    stacked ``layers`` leaves, with embed/ln_f/unembed shared by
    reference — no second checkpoint, no copy of the kept weights.
    Works on quantized trees too: QTensor values AND their per-layer
    scales carry the leading layer axis (core.quant's scannable-weights
    convention), so a slice of either stays a valid QTensor.  At
    ``n_layers == cfg.n_layers`` this IS the target model, which is the
    acceptance upper-bound sanity check the speculative tests pin."""
    out = dict(params)
    out["layers"] = jax.tree_util.tree_map(lambda x: x[:n_layers],
                                           params["layers"])
    return out


def _layer_fwd(cfg: ArchConfig, mode: QuantMode, x: Array, lp: dict,
               positions: Array) -> Array:
    acfg = attn_config(cfg)
    h = norm_apply(cfg, lp["ln_attn"], x)
    attn_out, _ = L.attention(lp["attn"], h, acfg, mode=mode,
                              positions=positions)
    x = x + attn_out
    h = norm_apply(cfg, lp["ln_mlp"], x)
    x = x + L.mlp(lp["mlp"], h, gated=cfg.gated_mlp,
                  activation=cfg.activation, mode=mode)
    return constrain(x, "act")


def forward(params: dict, tokens: Array, cfg: ArchConfig, *,
            mode: QuantMode = FP, remat: bool = True) -> Array:
    """Full-sequence forward (training / prefill).  tokens: (B, S)."""
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(x, lp):
        return _layer_fwd(cfg, mode, x, lp, positions), None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = norm_apply(cfg, params["ln_f"], x)
    head = params.get("unembed", params["embed"])
    return L.unembed(head, x)


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Stacked (L-leading) KV cache.  Sliding-window archs only keep the
    window (the paper's deterministic-footprint discipline).  With
    cfg.kv_quant the cache is int8 + per-(token, head) fp32 scales — half
    the bytes of bf16 (§Perf iteration C1, the paper's 8-bit insight)."""
    s_alloc = min(s_max, cfg.window) if cfg.window else s_max
    shape = (cfg.n_layers, batch, s_alloc, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32)}
    zeros = jnp.zeros(shape, dtype)
    return {"k": zeros, "v": jnp.zeros_like(zeros)}


def init_paged_cache(cfg: ArchConfig, num_slots: int, s_max: int,
                     block_size: int, num_blocks: int,
                     dtype=jnp.bfloat16) -> dict:
    """Paged KV cache: physical blocks (L, NB, bs, KV, hd) plus a per-slot
    block table (num_slots, s_max // bs) int32.  Block 0 is the reserved
    trash block every unallocated entry points at.  Only full-attention
    archs page (a window's ring overwrite has no stable positional
    frontier to map through a table)."""
    if cfg.window:
        raise ValueError("paged KV cache requires full attention "
                         f"(window=None), got window={cfg.window}")
    if s_max % block_size:
        raise ValueError(f"s_max={s_max} must tile into whole blocks of "
                         f"{block_size}")
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads,
             cfg.head_dim)
    tables = jnp.zeros((num_slots, s_max // block_size), jnp.int32)
    if cfg.kv_quant:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
                "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
                "block_tables": tables}
    zeros = jnp.zeros(shape, dtype)
    return {"k": zeros, "v": jnp.zeros_like(zeros), "block_tables": tables}


def paged_block_axes(cache: dict) -> dict:
    """Physical-block (NB) axis of each paged cache leaf."""
    return {k: 1 for k in cache if k != "block_tables"}


def _stacked_cache_write(c: Array, new: Array, idx: Array) -> Array:
    """Append ``new`` (L, B, s, KV, hd) into the stacked cache
    (L, B, S, KV, hd) at sequence position ``idx`` — scalar () lockstep or
    (B,) per-row for the slot engine."""
    idx = jnp.asarray(idx)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice(c, new, (0, 0, idx, 0, 0))
    return jax.vmap(
        lambda cb, nb, ib: jax.lax.dynamic_update_slice(
            cb, nb, (0, ib, 0, 0)),
        in_axes=(1, 1, 0), out_axes=1)(c, new, idx)


def decode_step(params: dict, tokens: Array, cache: dict, cache_index: Array,
                cfg: ArchConfig, *, mode: QuantMode = FP
                ) -> Tuple[Array, dict]:
    """One decode step: tokens (B, 1) -> logits (B, 1, V), updated cache.

    ``cache_index`` is scalar () when the whole batch advances in lockstep
    (the classic decode loop) or a vector (B,) when every row is an
    independent request at its own position (the slot-based serving
    engine): positions, cache writes and masks all become per-row.

    For sliding-window archs the cache is a ring buffer of size window
    (write position = cache_index % window).
    """
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    cache_index = jnp.asarray(cache_index)
    if cache_index.ndim:                    # (B,): per-slot positions
        positions = cache_index[:, None] + jnp.arange(s)[None, :]
    else:
        positions = cache_index + jnp.arange(s)[None, :]
    acfg = attn_config(cfg)
    tables = cache.get("block_tables")      # (B, MB) int32: paged mode
    if tables is not None:
        s_alloc = tables.shape[1] * cache["k"].shape[2]   # MB * bs
    else:
        s_alloc = cache["k"].shape[2]
    write_idx = cache_index % s_alloc if cfg.window else cache_index
    valid_len = jnp.minimum(cache_index + s, s_alloc)

    quant = "k_scale" in cache
    # Append-outside-scan (§Perf A4/C3): inside the scan each layer only
    # READS its cache slice and emits the new token's k/v; a single
    # dynamic_update_slice after the scan appends all layers at once.
    # Rewriting the full slice per layer (the naive functional update)
    # costs a slice write+read per layer per step — measured as the
    # dominant decode memory term for MHA-sized caches (kv>=16).  For small
    # GQA caches the per-layer rewrite is cheap and the big post-scan
    # update into an S-sharded cache costs more than it saves (measured:
    # starcoder2 37.8 ms vs 7.9 ms), so they keep the in-scan update.
    # Ring (windowed) caches also keep it: their overwrite slot must leave
    # the masked set.
    append = (tables is None and cfg.window is None
              and cfg.n_kv_heads >= 16)

    def body(x, lp_and_cache):
        if quant:
            lp, ck, cv, cks, cvs = lp_and_cache
            kv = (ck, cv, cks, cvs)
        else:
            lp, ck, cv = lp_and_cache
            kv = (ck, cv)
        h = norm_apply(cfg, lp["ln_attn"], x)
        attn_out, new_kv = L.attention(
            lp["attn"], h, acfg, mode=mode, positions=positions,
            kv_cache=kv, cache_index=write_idx,
            valid_len=valid_len, positions_k=positions,
            append_only=append, block_tables=tables)
        x = x + attn_out
        h = norm_apply(cfg, lp["ln_mlp"], x)
        x = x + L.mlp(lp["mlp"], h, gated=cfg.gated_mlp,
                      activation=cfg.activation, mode=mode)
        return constrain(x, "act"), new_kv

    if quant:
        xs = (params["layers"], cache["k"], cache["v"],
              cache["k_scale"], cache["v_scale"])
        x, (nk, nv, nks, nvs) = jax.lax.scan(body, x, xs)
        if tables is not None:
            # paged append: scatter each row's new-token entry through its
            # block table into the physical pool (inactive rows' tables
            # point at trash block 0)
            new_cache = dict(cache)
            for key, new in (("k", nk), ("v", nv),
                             ("k_scale", nks), ("v_scale", nvs)):
                new_cache[key] = L.paged_append(cache[key], new, tables,
                                                write_idx, block_axis=1)
        elif append:
            w = _stacked_cache_write
            new_cache = {
                "k": w(cache["k"], nk, write_idx),
                "v": w(cache["v"], nv, write_idx),
                "k_scale": w(cache["k_scale"], nks, write_idx),
                "v_scale": w(cache["v_scale"], nvs, write_idx)}
        else:
            new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    else:
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        if tables is not None:
            new_cache = dict(cache)
            new_cache["k"] = L.paged_append(cache["k"], nk, tables,
                                            write_idx, block_axis=1)
            new_cache["v"] = L.paged_append(cache["v"], nv, tables,
                                            write_idx, block_axis=1)
        elif append:
            w = _stacked_cache_write
            new_cache = {"k": w(cache["k"], nk, write_idx),
                         "v": w(cache["v"], nv, write_idx)}
        else:
            new_cache = {"k": nk, "v": nv}
    x = norm_apply(cfg, params["ln_f"], x)
    head = params.get("unembed", params["embed"])
    logits = L.unembed(head, x)
    return logits, new_cache
