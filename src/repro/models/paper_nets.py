"""The paper's six production NNs (Table 1) as runnable JAX models.

Weight counts match Table 1 (the roofline-relevant quantity; exact internal
topologies are not public).  All matmuls route through the quantized
`linear`, so these run the paper's actual int8 serving path; the serving
example drives them through the Table 4 batch scheduler.

- MLP0/MLP1: stacks of FC+ReLU layers (RankBrain-like).
- LSTM0/LSTM1: stacked LSTM cells, scan over time (GNM Translate subset).
- CNN0: AlphaGo-style 19x19 board net (16 conv layers of 256 3x3 filters).
- CNN1: Inception-like conv stack + 4 FC tail layers.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_apps import PaperAppConfig
from repro.core.qlinear import FP, QuantMode, init_linear, linear
from repro.core.quant import QTensor

Array = jax.Array


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp_app(key, cfg: PaperAppConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, len(cfg.widths))
    layers = []
    d_prev = cfg.widths[0]
    for k, w in zip(keys, cfg.widths):
        layers.append(init_linear(k, d_prev, w, bias=True, dtype=dtype))
        d_prev = w
    return {"layers": layers}


def mlp_app(params: dict, x: Array, *, mode: QuantMode = FP) -> Array:
    for i, lp in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        x = linear(lp, x, activation="none" if last else "relu", mode=mode)
    return x


# ---------------------------------------------------------------------------
# LSTMs
# ---------------------------------------------------------------------------

def init_lstm_app(key, cfg: PaperAppConfig, dtype=jnp.float32) -> dict:
    """n_cells stacked LSTM cells of width `hidden`; 4 gate matmuls per cell
    on [x; h] (the paper's '24 FC layers' for LSTM0 = 6 cells x 4 gates)."""
    keys = jax.random.split(key, cfg.n_cells)
    cells = []
    for k in keys:
        cells.append({
            "w": init_linear(k, 2 * cfg.hidden, 4 * cfg.hidden, bias=True,
                             dtype=dtype)})
    return {"cells": cells}


def _lstm_cell(cp: dict, x: Array, h: Array, c: Array, mode: QuantMode):
    z = linear(cp["w"], jnp.concatenate([x, h], axis=-1), mode=mode)
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def lstm_app(params: dict, x_seq: Array, *, mode: QuantMode = FP) -> Array:
    """x_seq: (B, T, hidden) -> final hidden state (B, hidden)."""
    b, t, d = x_seq.shape
    n = len(params["cells"])
    h = jnp.zeros((n, b, d), x_seq.dtype)
    c = jnp.zeros((n, b, d), x_seq.dtype)

    def step(carry, x_t):
        h, c = carry
        inp = x_t
        hs, cs = [], []
        for i, cp in enumerate(params["cells"]):
            hi, ci = _lstm_cell(cp, inp, h[i], c[i], mode)
            hs.append(hi)
            cs.append(ci)
            inp = hi
        return (jnp.stack(hs), jnp.stack(cs)), None

    (h, c), _ = jax.lax.scan(step, (h, c), x_seq.swapaxes(0, 1))
    return h[-1]


# ---------------------------------------------------------------------------
# CNNs
# ---------------------------------------------------------------------------

def init_cnn_app(key, cfg: PaperAppConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, len(cfg.conv_channels) + len(cfg.fc_tail))
    convs = []
    c_prev = cfg.conv_channels[0]
    for k, c in zip(keys, cfg.conv_channels):
        # He init: preserves activation scale through deep ReLU conv stacks
        w = (jax.random.truncated_normal(k, -2, 2, (3, 3, c_prev, c),
                                         jnp.float32)
             * (2.0 / (9 * c_prev)) ** 0.5).astype(dtype)
        convs.append({"w": w, "b": jnp.zeros((c,), dtype)})
        c_prev = c
    fcs = []
    d_prev = None
    for k, w in zip(keys[len(cfg.conv_channels):], cfg.fc_tail):
        d_prev = d_prev or cfg.fc_tail[0]
        fcs.append(init_linear(k, d_prev, w, bias=True, dtype=dtype))
        d_prev = w
    return {"convs": convs, "fcs": fcs}


def _conv2d(w, x):
    if isinstance(w, QTensor):
        w = w.dequantize(jnp.float32).astype(x.dtype)  # weight-only quant
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def cnn_app(params: dict, x: Array, *, mode: QuantMode = FP) -> Array:
    """x: (B, H, W, C)."""
    for cp in params["convs"]:
        b = cp["b"]
        x = jnp.maximum(_conv2d(cp["w"], x) + b[None, None, None], 0.0)
    if params["fcs"]:
        x = jnp.mean(x, axis=(1, 2))
        # project pooled features to the first FC width
        d_in = params["fcs"][0]["w"].shape[-2] if not isinstance(
            params["fcs"][0]["w"], QTensor) else \
            params["fcs"][0]["w"].values.shape[-2]
        reps = -(-d_in // x.shape[-1])
        x = jnp.tile(x, (1, reps))[:, :d_in]
        for i, lp in enumerate(params["fcs"]):
            last = i == len(params["fcs"]) - 1
            x = linear(lp, x, activation="none" if last else "relu",
                       mode=mode)
    return x


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def init_app(key, cfg: PaperAppConfig, dtype=jnp.float32) -> dict:
    return {"mlp": init_mlp_app, "lstm": init_lstm_app,
            "cnn": init_cnn_app}[cfg.kind](key, cfg, dtype)


def apply_app(params: dict, cfg: PaperAppConfig, x: Array, *,
              mode: QuantMode = FP) -> Array:
    return {"mlp": mlp_app, "lstm": lstm_app,
            "cnn": cnn_app}[cfg.kind](params, x, mode=mode)


def app_input(cfg: PaperAppConfig, batch: int, key=None,
              dtype=jnp.float32) -> Array:
    key = key if key is not None else jax.random.PRNGKey(0)
    if cfg.kind == "mlp":
        return jax.random.normal(key, (batch, cfg.widths[0]), dtype)
    if cfg.kind == "lstm":
        return jax.random.normal(key, (batch, 8, cfg.hidden), dtype)
    return jax.random.normal(
        key, (batch, cfg.spatial, cfg.spatial, cfg.conv_channels[0]), dtype)


def weight_count(params) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QTensor)):
        total += (int(jnp.prod(jnp.array(leaf.shape)))
                  if isinstance(leaf, QTensor) else leaf.size)
    return total
