"""Mamba-2 (SSD, state-space duality) — attention-free LM [arXiv:2405.21060].

The SSD layer computes y_t = C_t^T h_t,  h_t = a_t h_{t-1} + dt_t B_t x_t^T
with scalar-per-head decay a_t = exp(dt_t * A).  The *chunked* algorithm
(the paper's contribution) splits the sequence into chunks of Q tokens:

  intra-chunk: a masked (C_q B_k^T)-style "attention" matmul — MXU-friendly;
  inter-chunk: a small recurrence over per-chunk states (B, H, hd, N),
               carried by lax.scan.

This gives O(S*Q) work instead of O(S^2) -> the long_500k cell is runnable.
Training/prefill use the chunked path; decode is the O(1) state update.

Numerics note (paper tie-in): the recurrent state h accumulates in fp32 —
the same reasoning as the TPU's 32-bit accumulators; in/out projections run
through the quantized `linear` like every other matmul.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qlinear import FP, QuantMode, init_linear, linear
from repro.models import layers as L
from repro.runtime.sharding import constrain

Array = jax.Array


def _segsum(log_a: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} log_a[..., k]
    for j < i (lower-triangular), -inf above the diagonal."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_(j..i]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def init_ssd_layer(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, din, n, nh = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # fused in_proj: [x (din), z (din), B (n), C (n), dt (nh)]
    return {
        "norm": L.init_rmsnorm(d, dtype),
        "in_proj": init_linear(k1, d, 2 * din + 2 * n + nh, bias=False,
                               dtype=dtype),
        "conv_w": (jax.random.truncated_normal(
            k2, -2, 2, (cfg.conv_width, din + 2 * n), jnp.float32)
            * 0.3).astype(dtype),
        "conv_b": jnp.zeros((din + 2 * n,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": init_linear(k3, din, d, bias=False, dtype=dtype,
                                scale=din ** -0.5),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 state: Array = None) -> Tuple[Array, Array]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (width, C).

    Returns (out, new_state) where state is the last (width-1) inputs
    (decode carries it)."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None].astype(x.dtype)
              for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else \
        jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return jax.nn.silu(out + b[None, None].astype(x.dtype)), new_state


def _ssd_chunked(xh: Array, dt: Array, a_log: Array, Bm: Array, Cm: Array,
                 chunk: int) -> Array:
    """Chunked SSD scan.

    xh: (B, S, H, hd); dt: (B, S, H); Bm, Cm: (B, S, N).
    Returns y: (B, S, H, hd).  fp32 state.
    """
    b, s, h, hd = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // q

    # chunk views: (nc, B, q, ...)
    def chunked(t):
        return t.reshape(b, nc, q, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = map(chunked, (xh, dt, Bm, Cm))
    A = -jnp.exp(a_log)                                   # (H,)
    log_a = dtc.astype(jnp.float32) * A[None, None, None]  # (nc,B,q,H)<=0

    def per_chunk(state, inp):
        xq, dtq, bq, cq, la = inp          # (B,q,H,hd) (B,q,H) (B,q,N) ...
        la_h = la.transpose(0, 2, 1)                       # (B,H,q)
        seg = _segsum(la_h)                                # (B,H,q,q)
        decay = jnp.exp(seg)                               # lower-tri
        # intra-chunk: scores (B,H,q,q) = C_i . B_j * decay * dt_j
        scores = jnp.einsum("bin,bjn->bij", cq.astype(jnp.float32),
                            bq.astype(jnp.float32))
        scores = scores[:, None] * decay * \
            dtq.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhd->bihd", scores,
                             xq.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(jnp.cumsum(la_h, axis=-1))      # (B,H,q)
        y_inter = jnp.einsum("bin,bhdn,bhi->bihd",
                             cq.astype(jnp.float32), state, decay_in)
        # state update: h' = a_total * h + sum_j decay_rest_j dt_j B_j x_j
        a_total = jnp.exp(jnp.sum(la_h, axis=-1))          # (B,H)
        decay_rest = jnp.exp(jnp.sum(la_h, axis=-1, keepdims=True)
                             - jnp.cumsum(la_h, axis=-1))  # (B,H,q)
        contrib = jnp.einsum("bjn,bjhd,bhj,bjh->bhdn",
                             bq.astype(jnp.float32), xq.astype(jnp.float32),
                             decay_rest, dtq.astype(jnp.float32))
        new_state = a_total[..., None, None] * state + contrib
        return new_state, (y_intra + y_inter).astype(xh.dtype)

    state0 = jnp.zeros((b, h, hd, n), jnp.float32)
    state0 = constrain(state0, "ssm_state")
    _, ys = jax.lax.scan(per_chunk, state0, (xc, dtc, Bc, Cc, log_a))
    y = ys.swapaxes(0, 1).reshape(b, nc * q, h, hd)
    return y[:, :s]


def ssd_layer(p: dict, x: Array, cfg: ArchConfig, *, mode: QuantMode = FP,
              state: dict = None) -> Tuple[Array, dict]:
    """One Mamba-2 block.  state=None -> chunked full-sequence;
    state={'h','conv'} -> single-step decode."""
    b, s, d = x.shape
    din, n, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    h_in = L.rmsnorm(p["norm"], x)
    proj = linear(p["in_proj"], h_in, mode=mode)
    xz, z, Bm, Cm, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])       # (B,S,H)

    conv_in = jnp.concatenate([xz, Bm, Cm], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    xz, Bm, Cm = jnp.split(conv_out, [din, din + n], axis=-1)
    xh = xz.reshape(b, s, nh, hd)

    if state is None:
        y = _ssd_chunked(xh, dt, p["a_log"], Bm, Cm, cfg.ssm_chunk)
        new_h = None
    else:
        # O(1) decode: h' = a h + dt B x ; y = C.h
        hst = state["h"]                                   # (B,H,hd,N)
        a_step = jnp.exp(dt[:, 0].astype(jnp.float32)
                         * (-jnp.exp(p["a_log"]))[None])   # (B,H)
        contrib = jnp.einsum("bn,bhd,bh->bhdn", Bm[:, 0].astype(jnp.float32),
                             xh[:, 0].astype(jnp.float32),
                             dt[:, 0].astype(jnp.float32))
        new_h = a_step[..., None, None] * hst + contrib
        new_h = constrain(new_h, "ssm_state")
        y = jnp.einsum("bn,bhdn->bhd", Cm[:, 0].astype(jnp.float32),
                       new_h)[:, None].reshape(b, 1, nh, hd)

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = (y.reshape(b, s, din) * jax.nn.silu(z.astype(jnp.float32))
         ).astype(x.dtype)
    out = linear(p["out_proj"], y, mode=mode)
    new_state = None if state is None else {"h": new_h, "conv": new_conv}
    return x + constrain(out, "act"), new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_ssd_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": L.init_embedding(ke, cfg.vocab, cfg.d_model, dtype),
        "layers": stacked,
        "ln_f": L.init_rmsnorm(cfg.d_model, dtype),
    }


def forward(params: dict, tokens: Array, cfg: ArchConfig, *,
            mode: QuantMode = FP, remat: bool = True) -> Array:
    x = L.embed(params["embed"], tokens)

    def body(x, lp):
        out, _ = ssd_layer(lp, x, cfg, mode=mode)
        return out, None

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["ln_f"], x)
    return L.unembed(params["embed"], x)


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> dict:
    """Fixed-size state: (L, B, H, hd, N) fp32 + conv tail — independent of
    context length (the whole point for long_500k)."""
    nh, hd, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "h": jnp.zeros((cfg.n_layers, batch, nh, hd, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1,
                           cfg.d_inner + 2 * n), dtype),
    }


def mask_inactive_slots(old: dict, new: dict, active: Array) -> dict:
    """Freeze inactive slots' recurrent state (slot engine contract).

    Unlike a KV cache, the SSM state is NOT positional: there is no
    ``valid_len`` mask at read time that could hide a clobbered ``h`` or
    conv tail, so the fused slot step must leave inactive rows' state
    bitwise untouched.  ``active`` is (B,); state batch axis is 1."""
    return {
        "h": jnp.where(active[None, :, None, None, None],
                       new["h"], old["h"]),
        "conv": jnp.where(active[None, :, None, None],
                          new["conv"], old["conv"]),
    }


def decode_step(params: dict, tokens: Array, cache: dict, cache_index: Array,
                cfg: ArchConfig, *, mode: QuantMode = FP
                ) -> Tuple[Array, dict]:
    """One-token decode.  ``cache_index`` is scalar () (lockstep batch) or
    (B,) per-row for the slot engine.  The state is position-free, so the
    index's only job here is the *reset-at-zero scrub*: a row decoding its
    position-0 token by definition has no history, so its carried
    ``h``/conv state is zeroed before the update — that is what makes a
    reused slot's previous tenant invisible without scrubbing the pool."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens)
    ci = jnp.asarray(cache_index)
    fresh = jnp.broadcast_to(ci == 0, (b,))
    h_in = jnp.where(fresh[None, :, None, None, None],
                     jnp.zeros_like(cache["h"]), cache["h"])
    conv_in = jnp.where(fresh[None, :, None, None],
                        jnp.zeros_like(cache["conv"]), cache["conv"])

    def body(x, lp_and_state):
        lp, h, conv = lp_and_state
        out, new_state = ssd_layer(lp, x, cfg, mode=mode,
                                   state={"h": h, "conv": conv})
        return out, (new_state["h"], new_state["conv"])

    x, (new_h, new_conv) = jax.lax.scan(
        body, x, (params["layers"], h_in, conv_in))
    x = L.rmsnorm(params["ln_f"], x)
    return L.unembed(params["embed"], x), {"h": new_h, "conv": new_conv}
