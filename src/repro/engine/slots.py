"""Slot pool bookkeeping for the continuous-batching engine.

Pure host-side state (no jax): which slot serves which request, how far
each request has advanced, what it has generated.  The device-side cache
row `sid` belongs to whichever request currently owns slot `sid` — its
positional KV, its recurrent state, and (encdec/vlm) its primed
cross-attention K/V row.  A freed slot is reusable immediately: per-row
masking (positional KV reads stop at the slot's own frontier, cross
reads at the row's primed ``xlen``), the recurrent families' reset-at-
position-0 rule, and the prime dispatch overwriting the whole cross row
at the next admission make stale cache contents invisible, so there is
nothing to scrub between tenants.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple


class RequestTooLong(ValueError):
    """Typed admission rejection: the request cannot fit the cache
    (prompt + max_new exceeds ``max_seq``, or needs more KV blocks than
    the whole pool holds).  Raised at validation/admission time so an
    oversized request can never silently overrun a slot row."""


@dataclasses.dataclass
class SlotState:
    """One slot's tenancy: the request it serves and its progress.

    ``model`` is the slot's model-lane tag (None on a single-model
    engine): stamped at pool construction, never per-request — a pool
    belongs to exactly one lane, so a slot can never be re-tagged to
    another model's cache rows (decode-contract rule 8)."""
    sid: int
    model: Optional[str] = None
    rid: int = -1
    prompt: Tuple[int, ...] = ()
    max_new: int = 0
    pos: int = 0                      # tokens fed so far (prompt + generated)
    chunk_left: int = 0               # prompt tokens still owed to the
                                      # chunked-prefill step (0 = rides the
                                      # fused slot step)
    generated: Optional[List[int]] = None
    arrival_s: float = 0.0
    admit_s: float = 0.0
    deadline_s: float = float("inf")
    first_token_s: float = -1.0
    # paged KV cache (engine with block_size set): the physical block ids
    # this slot's logical positions map to (entry j covers positions
    # [j*block_size, (j+1)*block_size)), the request's prefix hash-chain
    # keys, and how many leading keys are registered for sharing
    block_table: Optional[List[int]] = None
    prompt_keys: Tuple = ()
    registered: int = 0
    # overload robustness: the request's SLO class, how many times this
    # tenancy's dispatch has been retried after an injected/real fault,
    # and how many times the request has been preempted so far
    priority: str = "interactive"
    retries: int = 0
    preemptions: int = 0
    # speculative decoding: how many tokens of the COMMITTED fed history
    # the draft model's cache has consumed (the draft-position frontier).
    # Always <= pos; the engine teacher-forces the gap through the draft
    # before proposing, which is also what rebuilds the draft after a
    # preemption/resume or slot reuse (alloc resets it to 0).
    draft_pos: int = 0

    @property
    def active(self) -> bool:
        return self.rid >= 0

    @property
    def in_prefill(self) -> bool:
        return self.active and self.pos < len(self.prompt)

    def next_input(self) -> int:
        """Token to feed this tick: prompt (teacher-forced) or last sample."""
        if self.pos < len(self.prompt):
            return self.prompt[self.pos]
        return self.generated[-1]

    def done(self) -> bool:
        return self.active and len(self.generated) >= self.max_new


class SlotPool:
    """Fixed pool of ``num_slots`` KV-cache slots: alloc on admission,
    free on retirement, reuse immediately.

    ``max_seq`` (when given) is the slot row's capacity in cache
    positions: ``alloc`` rejects any request whose ``prompt + max_new``
    would overrun it with the typed :class:`RequestTooLong`, so the
    admission layer cannot hand a slot to a request the device cache
    cannot hold."""

    def __init__(self, num_slots: int, max_seq: Optional[int] = None,
                 model: Optional[str] = None):
        self.num_slots = num_slots
        self.max_seq = max_seq
        self.model = model               # lane tag; None = single-model
        self.slots = [SlotState(sid=i, model=model)
                      for i in range(num_slots)]
        self._free = list(range(num_slots - 1, -1, -1))   # pop() -> slot 0 first

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_count(self) -> int:
        return self.num_slots - len(self._free)

    def active_slots(self) -> List[SlotState]:
        return [s for s in self.slots if s.active]

    def alloc(self, rid: int, prompt: Tuple[int, ...], max_new: int, *,
              now: float, arrival_s: float,
              deadline_s: float = float("inf"),
              priority: str = "interactive") -> SlotState:
        if not self._free:
            raise RuntimeError("no free slot (admission must respect "
                               "free_count)")
        if not prompt:
            raise ValueError(f"request {rid}: empty prompt")
        if self.max_seq is not None and len(prompt) + max_new > self.max_seq:
            raise RequestTooLong(
                f"request {rid} needs {len(prompt) + max_new} cache "
                f"positions > max_seq={self.max_seq}")
        st = self.slots[self._free.pop()]
        st.rid, st.prompt, st.max_new = rid, tuple(prompt), max_new
        st.pos, st.chunk_left, st.generated = 0, 0, []
        st.arrival_s, st.admit_s, st.deadline_s = arrival_s, now, deadline_s
        st.first_token_s = -1.0
        st.block_table, st.prompt_keys, st.registered = None, (), 0
        st.priority, st.retries, st.preemptions = priority, 0, 0
        st.draft_pos = 0
        return st

    def free(self, sid: int) -> None:
        st = self.slots[sid]
        assert st.active, sid
        st.rid = -1
        st.prompt, st.generated = (), None
        self._free.append(sid)


class BlockPool:
    """Fixed pool of physical KV blocks for the paged cache: a free list,
    per-block refcounts, and a prefix-hash registry for shared blocks.

    Block 0 is the reserved *trash* block: it is never allocated, every
    unallocated/inactive table entry points at it, so inactive rows'
    per-tick scatter-writes land there harmlessly, and reads never see
    it because attention masks positions past each row's own frontier.

    Sharing is copy-on-extend: a registered block is immutable (its
    logical positions hold a fully-written prompt-prefix block, keyed by
    the exact token chain that produced it), extra refs only ever read
    it, and each tenant's own writes always land in privately allocated
    blocks.  ``alloc`` therefore never hands out a block whose refcount
    is nonzero, and ``release`` drops the hash entry the moment the last
    ref goes away so a recycled block can never be found by lookup."""

    def __init__(self, num_blocks: int, block_size: int,
                 model: Optional[str] = None):
        self.model = model               # lane tag; None = single-model.
        # A BlockPool belongs to exactly one model lane: its free list,
        # refcounts, and prefix-hash registry are all lane-private, so
        # paged sharing can never cross models — no key collision or
        # refcount bug could hand one model another model's block.
        if num_blocks < 2:
            raise ValueError(f"num_blocks must be >= 2 (block 0 is the "
                             f"reserved trash block), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.refcounts = [0] * num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> block 1
        self._hash_to_block: Dict[Any, int] = {}
        self._block_to_hash: Dict[int, Any] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self) -> int:
        """Take a private block (refcount 0 -> 1)."""
        if not self._free:
            raise RuntimeError("KV block pool exhausted (admission must "
                               "respect free_blocks)")
        bid = self._free.pop()
        assert self.refcounts[bid] == 0, bid
        self.refcounts[bid] = 1
        return bid

    def ref(self, bid: int) -> None:
        """Add a ref to a live block (shared-prefix hit)."""
        if bid <= 0 or self.refcounts[bid] <= 0:
            raise RuntimeError(f"ref on dead block {bid}")
        self.refcounts[bid] += 1

    def release(self, bid: int) -> None:
        """Drop one ref; the last ref frees the block and evicts its
        hash entry so no future lookup can alias the recycled block."""
        if bid <= 0 or self.refcounts[bid] <= 0:
            raise RuntimeError(f"release on dead block {bid} "
                               f"(refcount must never go negative)")
        self.refcounts[bid] -= 1
        if self.refcounts[bid] == 0:
            key = self._block_to_hash.pop(bid, None)
            if key is not None:
                del self._hash_to_block[key]
            self._free.append(bid)

    def register(self, key: Any, bid: int) -> None:
        """Publish a fully-written prompt block for prefix sharing."""
        if self.refcounts[bid] <= 0:
            raise RuntimeError(f"register of dead block {bid}")
        if key not in self._hash_to_block:
            self._hash_to_block[key] = bid
            self._block_to_hash[bid] = key

    def lookup(self, key: Any) -> Optional[int]:
        return self._hash_to_block.get(key)
