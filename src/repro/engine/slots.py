"""Slot pool bookkeeping for the continuous-batching engine.

Pure host-side state (no jax): which slot serves which request, how far
each request has advanced, what it has generated.  The device-side cache
row `sid` belongs to whichever request currently owns slot `sid` — its
positional KV, its recurrent state, and (encdec/vlm) its primed
cross-attention K/V row.  A freed slot is reusable immediately: per-row
masking (positional KV reads stop at the slot's own frontier, cross
reads at the row's primed ``xlen``), the recurrent families' reset-at-
position-0 rule, and the prime dispatch overwriting the whole cross row
at the next admission make stale cache contents invisible, so there is
nothing to scrub between tenants.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class SlotState:
    """One slot's tenancy: the request it serves and its progress."""
    sid: int
    rid: int = -1
    prompt: Tuple[int, ...] = ()
    max_new: int = 0
    pos: int = 0                      # tokens fed so far (prompt + generated)
    chunk_left: int = 0               # prompt tokens still owed to the
                                      # chunked-prefill step (0 = rides the
                                      # fused slot step)
    generated: Optional[List[int]] = None
    arrival_s: float = 0.0
    admit_s: float = 0.0
    deadline_s: float = float("inf")
    first_token_s: float = -1.0

    @property
    def active(self) -> bool:
        return self.rid >= 0

    @property
    def in_prefill(self) -> bool:
        return self.active and self.pos < len(self.prompt)

    def next_input(self) -> int:
        """Token to feed this tick: prompt (teacher-forced) or last sample."""
        if self.pos < len(self.prompt):
            return self.prompt[self.pos]
        return self.generated[-1]

    def done(self) -> bool:
        return self.active and len(self.generated) >= self.max_new


class SlotPool:
    """Fixed pool of ``num_slots`` KV-cache slots: alloc on admission,
    free on retirement, reuse immediately."""

    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.slots = [SlotState(sid=i) for i in range(num_slots)]
        self._free = list(range(num_slots - 1, -1, -1))   # pop() -> slot 0 first

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_count(self) -> int:
        return self.num_slots - len(self._free)

    def active_slots(self) -> List[SlotState]:
        return [s for s in self.slots if s.active]

    def alloc(self, rid: int, prompt: Tuple[int, ...], max_new: int, *,
              now: float, arrival_s: float,
              deadline_s: float = float("inf")) -> SlotState:
        if not self._free:
            raise RuntimeError("no free slot (admission must respect "
                               "free_count)")
        if not prompt:
            raise ValueError(f"request {rid}: empty prompt")
        st = self.slots[self._free.pop()]
        st.rid, st.prompt, st.max_new = rid, tuple(prompt), max_new
        st.pos, st.chunk_left, st.generated = 0, 0, []
        st.arrival_s, st.admit_s, st.deadline_s = arrival_s, now, deadline_s
        st.first_token_s = -1.0
        return st

    def free(self, sid: int) -> None:
        st = self.slots[sid]
        assert st.active, sid
        st.rid = -1
        st.prompt, st.generated = (), None
        self._free.append(sid)
