"""Replica router: the fleet front-end over N slot engines.

The paper's serving tier is not one chip: datacenter traffic lands on a
fleet of identical accelerators behind a front-end, and the per-chip
determinism argument (Table 4) is what lets the FLEET promise a p99 —
each replica's tail is predictable, so placement is the only new source
of variance.  This module is that front-end in the repo's offline,
deterministic idiom:

- :class:`ReplicaRouter` owns N :class:`~repro.engine.engine.Engine`
  replicas (each with its own device state — caches, pools, compiled
  steps; decode-contract rule 9: the router holds NO device state).
- ``route`` assigns each request, in arrival order, to the replica with
  the LOWEST projected slot occupancy — a virtual-time projection that
  admits a request only where the replica's own
  ``core.batching.AdmissionPolicy`` would admit it under the projected
  state.  The router therefore never routes an admission a replica's
  policy would reject (property-tested in ``tests/test_router.py``);
  a request every replica's quotas permanently refuse is returned as
  typed ``refused``, never silently dropped.
- ``serve`` runs the plan: each replica serves its assigned sub-trace
  (sequentially here — replicas are independent, so any execution order
  yields the same bits), and the per-replica ``EngineReport``s roll up
  into one :class:`RouterReport`.

Because replicas share no state, a request's output depends only on
which replica's engine served it — and every replica is configured
identically — so routed outputs are bit-for-bit the outputs of a single
engine serving the same sub-trace.  ``benchmarks/serving_bench.py``'s
``router_smoke`` pins that against the sequential reference.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import batching as bt
from repro.engine.engine import Engine, EngineReport
from repro.engine.dispatch import EngineRequest, RequestResult

# projected per-token slot-hold time when a replica's policy models
# service time as free (the default Engine policy): the engine's default
# virtual tick_s, so projections still spread load instead of
# degenerating to "everything fits replica 0"
_FALLBACK_EST_S = 1e-3


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Audit record for one admission the router made: the projected
    state under which the target replica's AdmissionPolicy said yes.
    The property test replays ``policy.decide`` on exactly this state
    and asserts it launches."""
    rid: int
    replica: str
    now: float
    capacity: int
    active_by_class: Dict


@dataclasses.dataclass
class RoutePlan:
    assignments: Dict[str, List[EngineRequest]]
    refused: List[EngineRequest]
    decisions: List[RouteDecision]


@dataclasses.dataclass
class RouterReport:
    """Fleet rollup: per-replica reports plus the merged view the caller
    actually consumes (rid-sorted results spanning every replica and the
    refused set, fleet throughput over the slowest replica's clock)."""
    results: List[RequestResult]
    replicas: Dict[str, EngineReport]
    replica_names: List[str]
    refused: int
    generated_tokens: int
    duration_s: float              # slowest replica's engine clock
    tokens_per_s: float            # fleet tokens over that clock
    goodput_tokens_per_s: float
    p99_latency_s: float
    mean_ttft_s: float
    leaked_blocks: int
    replica_occupancy: Dict[str, float]   # per-replica mean occupancy
    replica_requests: Dict[str, int]      # per-replica assigned count

    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.tokens for r in self.results}


class _Projection:
    """One replica's virtual-time occupancy projection: a min-heap of
    (projected_finish, quota_keys) for every routed-but-unfinished
    request, plus the quota usage those requests hold."""

    def __init__(self, name: str, eng: Engine):
        self.name = name
        self.eng = eng
        self.heap: List[Tuple[float, int, Tuple]] = []
        self.active_by_class: Dict = {}
        self._push_seq = 0
        est = eng.policy.service_time(1)
        self.est_s = est if est > 0 else _FALLBACK_EST_S

    def retire_until(self, now: float) -> None:
        while self.heap and self.heap[0][0] <= now:
            _, _, keys = heapq.heappop(self.heap)
            for k in keys:
                n = self.active_by_class.get(k, 0) - 1
                if n > 0:
                    self.active_by_class[k] = n
                else:
                    self.active_by_class.pop(k, None)

    @property
    def active(self) -> int:
        return len(self.heap)

    @property
    def occupancy(self) -> float:
        return self.active / self.eng.num_slots

    def class_key(self, r: EngineRequest):
        return ((getattr(r, "model", None), r.priority)
                if self.eng.multi else r.priority)

    def admits(self, r: EngineRequest, now: float) -> Optional[RouteDecision]:
        """Would this replica's own AdmissionPolicy admit ``r`` right
        now, under the projected state?  The policy is consulted with
        the projected free-slot capacity and projected per-class usage —
        the same inputs the live engine's scheduler would hand it."""
        cap = self.eng.num_slots - self.active
        if cap <= 0:
            return None
        act = self.eng.policy.decide(
            now, [r.deadline_s], next_arrival=None, capacity=cap,
            classes=[self.class_key(r)],
            active_by_class=dict(self.active_by_class))
        if not (act.launch and act.batch >= 1):
            return None
        return RouteDecision(rid=r.rid, replica=self.name, now=now,
                             capacity=cap,
                             active_by_class=dict(self.active_by_class))

    def commit(self, r: EngineRequest, now: float) -> None:
        hold = (len(r.prompt) + r.max_new_tokens) * self.est_s
        keys = bt.AdmissionPolicy._quota_keys(self.class_key(r))
        self._push_seq += 1
        heapq.heappush(self.heap, (now + hold, self._push_seq, keys))
        for k in keys:
            self.active_by_class[k] = self.active_by_class.get(k, 0) + 1


class ReplicaRouter:
    """Load-balance a request trace across N identically-configured
    engine replicas by projected slot occupancy.

    ``engines`` must be independently-constructed :class:`Engine`
    instances (they share NO device state); ``names`` labels them for
    reports and straggler attribution (default ``replica0..N-1``).
    """

    def __init__(self, engines: Sequence[Engine],
                 names: Optional[Sequence[str]] = None):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines = list(engines)
        if names is None:
            names = [e.name or f"replica{i}"
                     for i, e in enumerate(self.engines)]
        if len(names) != len(self.engines):
            raise ValueError(f"{len(names)} names for "
                             f"{len(self.engines)} engines")
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique: {list(names)}")
        self.names = list(names)
        for e, n in zip(self.engines, self.names):
            if e.name is None:
                e.name = n
        lane_sets = {frozenset(e.lanes) for e in self.engines}
        if len(lane_sets) != 1:
            raise ValueError(
                "replicas must serve the same model lanes; got "
                f"{sorted(sorted(map(repr, s)) for s in lane_sets)}")

    def route(self, requests: Sequence[EngineRequest]) -> RoutePlan:
        """Assign every request to a replica (or refuse it), in arrival
        order, deterministically.  Each request goes to the
        lowest-projected-occupancy replica whose AdmissionPolicy admits
        it; when every replica is projected full (or quota-blocked), the
        projection clock advances to the earliest projected finish and
        the request retries — bounded, because every retry retires at
        least one projected slot.  A request refused by every replica
        with an EMPTY projection is permanently unroutable (its quota
        key is hard-capped at zero everywhere) and lands in
        ``refused``."""
        projs = [_Projection(n, e)
                 for n, e in zip(self.names, self.engines)]
        assignments: Dict[str, List[EngineRequest]] = \
            {n: [] for n in self.names}
        refused: List[EngineRequest] = []
        decisions: List[RouteDecision] = []
        reqs = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        for r in reqs:
            now = r.arrival_s
            placed = False
            while True:
                for p in projs:
                    p.retire_until(now)
                # lowest projected occupancy first; index breaks ties so
                # the plan is deterministic
                order = sorted(range(len(projs)),
                               key=lambda i: (projs[i].occupancy, i))
                for i in order:
                    dec = projs[i].admits(r, now)
                    if dec is not None:
                        projs[i].commit(r, now)
                        assignments[projs[i].name].append(r)
                        decisions.append(dec)
                        placed = True
                        break
                if placed:
                    break
                # everyone said no: advance the projection clock past
                # the earliest projected finish anywhere and retry
                pending_finishes = [p.heap[0][0] for p in projs if p.heap]
                if not pending_finishes:
                    refused.append(r)      # unroutable even when idle
                    break
                now = max(now, min(pending_finishes))
                # strict progress: retire at least the entry we jumped to
                for p in projs:
                    p.retire_until(now)
        return RoutePlan(assignments=assignments, refused=refused,
                         decisions=decisions)

    def serve(self, requests: Sequence[EngineRequest],
              **serve_kwargs) -> RouterReport:
        """Route, then serve each replica's sub-trace and roll up.

        ``serve_kwargs`` pass through to every replica's
        :meth:`Engine.serve` unchanged (clock, tick_s, preemption,
        fault_plan, ...), so the fleet runs the same discipline as a
        single engine."""
        plan = self.route(requests)
        reports: Dict[str, EngineReport] = {}
        for name, eng in zip(self.names, self.engines):
            sub = plan.assignments[name]
            if sub:
                reports[name] = eng.serve(sub, **serve_kwargs)
        results: List[RequestResult] = []
        for rep in reports.values():
            results.extend(rep.results)
        for r in plan.refused:
            results.append(RequestResult(
                rid=r.rid, tokens=[], arrival_s=r.arrival_s,
                admit_s=-1.0, first_token_s=-1.0, finish_s=r.arrival_s,
                slot=-1, status="refused", priority=r.priority,
                deadline_s=r.deadline_s,
                model=getattr(r, "model", None)))
        results.sort(key=lambda r: r.rid)
        gen = sum(rep.generated_tokens for rep in reports.values())
        dur = max((rep.duration_s for rep in reports.values()),
                  default=0.0)
        lat = [r.latency_s for r in results if r.status == "ok"]
        ttft = [r.ttft_s for r in results if r.emitted]
        refused_n = len(plan.refused) + sum(rep.refused
                                            for rep in reports.values())
        return RouterReport(
            results=results,
            replicas=reports,
            replica_names=list(self.names),
            refused=refused_n,
            generated_tokens=gen,
            duration_s=dur,
            tokens_per_s=gen / dur if dur > 0 else 0.0,
            goodput_tokens_per_s=(
                sum(rep.goodput_tokens_per_s * rep.duration_s
                    for rep in reports.values()) / dur if dur > 0 else 0.0),
            p99_latency_s=bt.p99(lat),
            mean_ttft_s=float(np.mean(ttft)) if ttft else 0.0,
            leaked_blocks=sum(rep.leaked_blocks
                              for rep in reports.values()),
            replica_occupancy={n: reports[n].mean_occupancy
                               if n in reports else 0.0
                               for n in self.names},
            replica_requests={n: len(plan.assignments[n])
                              for n in self.names})
