"""Deterministic fault injection for the slot engine.

A :class:`FaultPlan` is a *seeded, reproducible* schedule of failures the
engine consults every tick — nothing here is random at run time, so a
chaos run replays bit-for-bit and a recovered trace can be diffed against
its fault-free control arm.  Three fault kinds cover the engine's real
failure surface:

``dispatch``
    The fused slot step "fails" (in production: an XLA runtime error, a
    device OOM, a preempted TPU donation).  The engine retries the
    dispatch; the plan can keep failing it until the designated culprit
    slot is evicted, modelling a poisoned input that deterministically
    kills the step.
``nan_logits``
    One slot's sampled token is replaced by the non-finite sentinel
    ``-1`` after the step, exactly what the in-graph finite guard emits
    when a slot's logits contain NaN/Inf (a corrupted cache row, an
    overflowed activation).
``torn_table``
    One slot's device block-table row is zeroed (all entries -> the
    reserved trash block 0) before dispatch — a torn/partial write.  The
    engine's table audit detects the divergence from its host mirror and
    repairs or evicts.

Faults target *ticks* (the engine's deterministic time base), not wall
clock, so plans compose with any trace.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

FAULT_KINDS = ("dispatch", "nan_logits", "torn_table")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled failure.

    ``tick``: engine tick (0-based, counted over dispatched fused steps)
    at which the fault fires.  ``kind``: one of :data:`FAULT_KINDS`.
    ``slot``: victim slot id; if the slot is inactive at the fault tick
    the fault targets the lowest active sid instead (a plan should not
    silently no-op because the trace shifted).  ``repeat``: for
    ``dispatch`` faults, how many consecutive retry attempts fail before
    the dispatch succeeds (a value >= the engine's ``max_retries``
    forces the culprit's eviction)."""
    tick: int
    kind: str
    slot: int = 0
    repeat: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.tick < 0 or self.slot < 0 or self.repeat < 1:
            raise ValueError(f"bad fault {self}")


class FaultPlan:
    """A fixed schedule of :class:`Fault`\\ s, consulted by the engine.

    The plan is stateless across runs (re-serving the same plan on the
    same trace reproduces the same failures) but keeps per-run counters
    (`fired`) so a report can assert every scheduled fault actually
    fired.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = sorted(faults, key=lambda f: f.tick)
        self.fired: List[Tuple[int, str, int]] = []   # (tick, kind, slot)
        self._dispatch_left: Dict[int, int] = {}      # tick -> remaining fails
        self._dispatch_victim: Dict[int, int] = {}    # tick -> bound culprit

    def __len__(self) -> int:
        return len(self.faults)

    def _victim(self, want: int, active_sids: Sequence[int]) -> Optional[int]:
        if not active_sids:
            return None
        return want if want in active_sids else min(active_sids)

    def dispatch_fault(self, tick: int, attempt: int,
                       active_sids: Sequence[int]) -> Optional[int]:
        """Should dispatch attempt ``attempt`` (0-based) at ``tick``
        fail?  Returns the culprit slot id, or None for a clean
        dispatch.  A ``repeat=r`` fault fails attempts 0..r-1; once the
        culprit slot is no longer active (the engine evicted it) the
        remaining repeats are cancelled — the poison left with the
        slot."""
        for f in self.faults:
            if f.kind != "dispatch" or f.tick != tick:
                continue
            if tick not in self._dispatch_victim:
                victim = self._victim(f.slot, active_sids)
                if victim is None:
                    return None
                self._dispatch_victim[tick] = victim
                self._dispatch_left[tick] = f.repeat
            victim = self._dispatch_victim[tick]
            if victim not in active_sids:
                return None        # culprit evicted: poison left with it
            if self._dispatch_left[tick] <= 0:
                return None
            self._dispatch_left[tick] -= 1
            self.fired.append((tick, "dispatch", victim))
            return victim
        return None

    def nonfinite_slots(self, tick: int,
                        active_sids: Sequence[int]) -> List[int]:
        """Slots whose sampled token this tick must be replaced by the
        non-finite sentinel (-1), emulating NaN/Inf logits."""
        out = []
        for f in self.faults:
            if f.kind == "nan_logits" and f.tick == tick:
                victim = self._victim(f.slot, active_sids)
                if victim is not None:
                    self.fired.append((tick, "nan_logits", victim))
                    out.append(victim)
        return out

    def torn_rows(self, tick: int,
                  active_sids: Sequence[int]) -> List[int]:
        """Slots whose device block-table row is torn (zeroed to the
        trash block) before this tick's dispatch."""
        out = []
        for f in self.faults:
            if f.kind == "torn_table" and f.tick == tick:
                victim = self._victim(f.slot, active_sids)
                if victim is not None:
                    self.fired.append((tick, "torn_table", victim))
                    out.append(victim)
        return out

    @classmethod
    def random(cls, seed: int, *, n_faults: int = 8,
               max_tick: int = 400, num_slots: int = 8,
               kinds: Sequence[str] = FAULT_KINDS,
               max_repeat: int = 2) -> "FaultPlan":
        """A seeded plan spreading ``n_faults`` failures over the run.
        Same seed -> same plan, always (``random.Random(seed)``, no
        global state)."""
        rng = random.Random(seed)
        faults = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            faults.append(Fault(
                tick=rng.randrange(max_tick),
                kind=kind,
                slot=rng.randrange(num_slots),
                repeat=rng.randint(1, max_repeat) if kind == "dispatch"
                else 1))
        return cls(faults)
