"""Admission frontend for the live engine.

``SlotScheduler`` owns the pending queue and consults the SAME
:class:`repro.core.batching.AdmissionPolicy` the virtual-time simulator
(`BatchQueue`) uses — the refactor's point is that "which requests launch
now?" is one decision procedure with two backends.  ``run_virtual``
replays a whole arrival trace through this scheduler under the
simulator's engine-busy-until-finish semantics, which is what the
equivalence property test compares against ``BatchQueue.run`` record for
record.
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Sequence

from repro.core import batching as bt


class SlotScheduler:
    """Pending queue + shared admission policy.

    Works on any request object with ``arrival_s``/``deadline_s``/``rid``
    attributes (``core.batching.Request`` or the engine's
    ``EngineRequest``).
    """

    def __init__(self, policy: bt.AdmissionPolicy):
        self.policy = policy
        self.pending: List = []          # sorted by (class rank, deadline)

    def push(self, req) -> None:
        """Class-first, deadline-second ordering.  Requests without a
        ``priority`` attribute (the simulator's ``core.batching.Request``)
        rank as interactive (rank 0), so a single-class queue keeps
        today's pure-deadline order — the simulator equivalence property
        is untouched."""
        bisect.insort(self.pending, req, key=lambda r: (
            bt.priority_rank(getattr(r, "priority", bt.PRIORITY_CLASSES[0])),
            r.deadline_s))

    def admit(self, now: float, capacity: int,
              next_arrival: Optional[float] = None, *,
              cost_fn=None, budget=None,
              active_by_class=None, key_fn=None) -> List:
        """Requests to admit right now into ``capacity`` free slots
        (possibly none: the policy may prefer to wait for more work).

        ``cost_fn(req) -> int`` + ``budget`` enable memory-aware
        admission (the paged KV engine): each pending request's
        worst-case block claim is priced and the policy shrinks the
        cohort until the summed claim fits what the pool has free
        (``budget`` may be a per-model mapping when ``key_fn`` yields
        ``(model, class)`` tuples — see ``AdmissionPolicy.decide``).

        ``active_by_class`` (quota key -> slots currently held)
        activates per-class quota admission when the policy has
        ``class_quotas``; quota-blocked requests are skipped, not
        barriers, so the policy returns explicit ``picks`` indices
        instead of a prefix length.

        ``key_fn(req)`` overrides how a pending request is classed —
        the multiplexed engine passes ``lambda r: (r.model,
        r.priority)`` so quotas meter ``(model, class)`` keys.  Setting
        it forces the class-aware picks path even with no quotas
        configured (which then reduces to the legacy prefix cohort);
        leaving it ``None`` preserves the single-model path exactly."""
        if capacity <= 0 or not self.pending:
            return []
        costs = ([cost_fn(r) for r in self.pending]
                 if cost_fn is not None else None)
        use_classes = bool(self.policy.class_quotas) or key_fn is not None
        if key_fn is not None:
            classes = [key_fn(r) for r in self.pending]
        else:
            classes = ([getattr(r, "priority", bt.PRIORITY_CLASSES[0])
                        for r in self.pending] if use_classes else None)
        act = self.policy.decide(
            now, [r.deadline_s for r in self.pending], next_arrival,
            capacity=capacity, costs=costs, budget=budget,
            classes=classes,
            active_by_class=active_by_class if use_classes else None)
        if not act.launch:
            return []
        if act.picks is not None:
            cohort = [self.pending[i] for i in act.picks]
            for i in sorted(act.picks, reverse=True):
                del self.pending[i]
            return cohort
        cohort = self.pending[:act.batch]
        del self.pending[:act.batch]
        return cohort

    def run_virtual(self, requests: Sequence[bt.Request]
                    ) -> List[bt.BatchRecord]:
        """Replay a trace under virtual time with the simulator's
        engine-busy-until-finish semantics, going through this
        scheduler's own ``push``/``admit`` path.  Must produce records
        identical to ``BatchQueue.run`` on the same trace — the
        property test for the policy extraction."""
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        records: List[bt.BatchRecord] = []
        service = self.policy.service_time
        i, now = 0, 0.0
        while i < len(reqs) or self.pending:
            while i < len(reqs) and reqs[i].arrival_s <= now:
                self.push(reqs[i])
                i += 1
            if not self.pending:
                now = reqs[i].arrival_s
                continue
            next_arrival = reqs[i].arrival_s if i < len(reqs) else None
            cohort = self.admit(now, self.policy.max_batch, next_arrival)
            if not cohort:                       # policy chose to wait
                if next_arrival is None or next_arrival <= now:
                    # Nothing left to wait FOR: a policy that declines a
                    # non-empty queue after the last arrival would spin
                    # forever (and `now = None` used to TypeError here).
                    # Surface it as a contract violation instead.
                    raise RuntimeError(
                        "AdmissionPolicy declined a non-empty pending queue "
                        f"with no future arrival to wait for (now={now!r}, "
                        f"next_arrival={next_arrival!r}, "
                        f"pending={len(self.pending)}); "
                        "run_virtual cannot make progress")
                now = next_arrival
                continue
            finish = now + service(len(cohort))
            records.append(bt.BatchRecord(
                now, finish, tuple(r.rid for r in cohort),
                all(finish <= r.deadline_s for r in cohort)))
            now = finish
        return records
