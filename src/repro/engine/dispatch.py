"""Dispatch core: the per-lane tick loop behind the Engine's policy face.

The engine splits into three layers (docs/architecture.md):

- ``Engine`` (engine.py) — policy + reporting: request validation,
  admission policy configuration, and ``EngineReport`` assembly.
- ``DispatchCore`` (this module) — mechanism: the per-lane tick loop,
  slot/block accounting, stash/exact-resume, and fault plumbing.  It
  consumes an engine's lanes and returns raw counters
  (:class:`DispatchOutcome`); it never computes aggregates.
- ``ExecutorBackend`` — the narrow seam the dispatch core runs compiled
  steps through: the five step builders behind the process-wide
  ``runtime.steps.cached_*`` memos.  :class:`SingleDeviceExecutor` is
  the legacy single-device step set; :class:`ShardedExecutor` runs the
  same builders under ``jax.experimental.shard_map`` on a
  tensor-parallel mesh axis (slot-axis sharding — bit-identical to the
  single-device backend by construction, see runtime/steps.py).

Everything host-side a request leaves behind between dispatches —
``SlotState`` progress, block tables, the preemption stash — lives
here, so an ``Engine`` is exactly "an admission policy and a report
assembler wired to a dispatch core".
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import batching as bt
from repro.core.qlinear import FP, QuantMode
from repro.engine.faults import FaultPlan
from repro.engine.scheduler import SlotScheduler
from repro.engine.slots import BlockPool, SlotPool
from repro.models import registry as R
from repro.runtime import steps as ST
from repro.runtime.watchdog import StepWatchdog


@dataclasses.dataclass(frozen=True)
class EngineRequest:
    rid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_s: float = float("inf")
    # encdec/vlm: the request's source embeddings (src_len, d_model) —
    # encoder frames / vision patches a prime dispatch turns into the
    # slot's cross-K/V row at admission.  src_len may be shorter than the
    # static source length; the pad is masked behind the row's xlen.
    source: Optional[np.ndarray] = dataclasses.field(
        default=None, compare=False, repr=False)
    # SLO class (see core.batching.PRIORITY_CLASSES): admission orders
    # and sheds cohorts class-first, per-class slot quotas cap how many
    # slots a class may hold, and preemption only ever evicts a slot of
    # strictly lower class than the request it makes room for
    priority: str = "interactive"
    # multi-model multiplexing: which admitted model lane serves this
    # request (must name a tag of Engine(models={...}); None on a
    # single-model engine).  Quotas then meter (model, class) keys —
    # see docs/serving.md, multi-model multiplexing.
    model: Optional[str] = None


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    arrival_s: float
    admit_s: float
    first_token_s: float
    finish_s: float
    slot: int
    dropped: bool = False             # retired before completing (deadline)
    # typed outcome: "ok" (completed), "dropped" (deadline miss, mirrors
    # the bool), "failed" (retired by fault recovery after max_retries),
    # "unfinished" (still in flight when the tick cap hit), "refused"
    # (its model lane was retired or never admitted — hot-swap)
    status: str = "ok"
    priority: str = "interactive"
    preemptions: int = 0              # times evicted + exactly resumed
    deadline_s: float = float("inf")
    model: Optional[str] = None       # serving model lane (None = single)

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def emitted(self) -> bool:
        """True once the request produced at least one token; ``ttft_s``
        is meaningless (the -1.0 sentinel) until then."""
        return self.first_token_s >= 0

    @property
    def ttft_s(self) -> float:
        """Admission-to-first-token: what chunked prefill shrinks.  Only
        defined when ``emitted`` — a request retired mid-prefill still
        carries the -1.0 sentinel, which aggregates must exclude."""
        return self.first_token_s - self.admit_s


@dataclasses.dataclass
class _Stash:
    """A preempted request's host-side progress, held between eviction
    and re-admission.  Device state is deliberately NOT kept: resume
    reconstructs every cache byte by teacher-forcing ``prompt +
    generated`` through the chunked-prefill path (decode is
    deterministic and the sampling key schedule is position-based, so
    the rebuilt run is bit-for-bit the never-preempted run) —
    "preempted state is reconstructed, never trusted"."""
    generated: List[int]
    first_token_s: float
    admit_s: float
    preemptions: int
    retries: int


# ---------------------------------------------------------------------------
# executor backends: the compiled step set behind the dispatch core
# ---------------------------------------------------------------------------

class ExecutorBackend:
    """The narrow interface the dispatch core runs device work through:
    five step providers, each returning a compiled callable with the
    exact signature of the corresponding ``runtime.steps.make_*_step``.

    Backends provide STEPS, not state — every device buffer (cache,
    tokens, index, block tables) is owned by the lane that calls the
    step, so two backends over the same config are interchangeable
    mid-process and comparable bit-for-bit (the conformance test in
    tests/test_dispatch.py pins the signatures)."""

    kind: str = "abstract"
    tp: int = 1                        # tensor-parallel width (1 = none)

    def validate(self, eng) -> None:
        """Reject engine shapes this backend cannot serve (called once
        at Engine construction, before any lane compiles a step)."""

    def slot_step(self, cfg: ArchConfig, *, mode: QuantMode,
                  temperature: float) -> Callable:
        raise NotImplementedError

    def chunk_step(self, cfg: ArchConfig, *, mode: QuantMode,
                   chunk: int) -> Callable:
        raise NotImplementedError

    def prime_step(self, cfg: ArchConfig, *, mode: QuantMode) -> Callable:
        raise NotImplementedError

    def verify_step(self, cfg: ArchConfig, *, mode: QuantMode, k: int,
                    temperature: float) -> Callable:
        raise NotImplementedError

    def propose_step(self, dcfg: ArchConfig, *, mode: QuantMode,
                     k: int) -> Callable:
        raise NotImplementedError


class SingleDeviceExecutor(ExecutorBackend):
    """The legacy step set: one device, one compiled step per (config,
    shape) from the process-wide ``cached_*`` memos — a dedicated
    engine and a multiplexed lane over the same config share one
    compilation."""

    kind = "single"

    def slot_step(self, cfg, *, mode, temperature):
        return ST.cached_slot_decode_step(cfg, mode=mode,
                                          temperature=temperature)

    def chunk_step(self, cfg, *, mode, chunk):
        return ST.cached_prefill_chunk_step(cfg, mode=mode, chunk=chunk)

    def prime_step(self, cfg, *, mode):
        return ST.cached_prime_step(cfg, mode=mode)

    def verify_step(self, cfg, *, mode, k, temperature):
        return ST.cached_verify_step(cfg, mode=mode, k=k,
                                     temperature=temperature)

    def propose_step(self, dcfg, *, mode, k):
        return ST.cached_draft_propose_step(dcfg, mode=mode, k=k)


class ShardedExecutor(ExecutorBackend):
    """Tensor-parallel step set: the same ``make_*_step`` builders run
    under ``jax.experimental.shard_map`` on the ``"model"`` axis of a
    host mesh (``launch.mesh.make_host_mesh``), sharded along the SLOT
    axis — each shard advances ``num_slots / tp`` rows with the full
    model replicated, which keeps every per-row float op in the exact
    order of the single-device step, so outputs are bit-for-bit
    identical (the parity gate in tests/test_sharded.py).  Attention
    heads and MoE experts could shard instead, but cross-shard psum
    reassociates float adds and loses bit parity — the slot axis is the
    sharding that costs nothing (per-row ``cache_index`` is batch-local
    already).

    Restricted to the XLA 0.4.x-safe forward-only subset: no
    collectives at all inside the step (feature-detected by
    ``runtime.steps.supports_sharded_serving``, the serving twin of
    ``supports_int8_grad_exchange``).  Sharded state is replica-private
    (decode-contract rule 9): the mesh lives inside this backend and
    never crosses an engine boundary."""

    kind = "sharded"

    def __init__(self, tp: Optional[int] = None):
        if not ST.supports_sharded_serving():
            raise RuntimeError(
                "sharded serving needs jax.experimental.shard_map "
                "(see supports_sharded_serving)")
        ndev = len(jax.devices())
        self.tp = int(tp) if tp is not None else ndev
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.tp > ndev:
            raise ValueError(
                f"tp={self.tp} exceeds the {ndev} visible device(s); "
                f"force a CPU mesh with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=N")

    def validate(self, eng) -> None:
        if eng.num_slots % self.tp:
            raise ValueError(
                f"num_slots={eng.num_slots} must divide by tp={self.tp} "
                f"(the pool shards along the slot axis)")

    def slot_step(self, cfg, *, mode, temperature):
        return ST.cached_sharded_slot_decode_step(
            cfg, mode=mode, temperature=temperature, tp=self.tp)

    def chunk_step(self, cfg, *, mode, chunk):
        return ST.cached_sharded_prefill_chunk_step(
            cfg, mode=mode, chunk=chunk, tp=self.tp)

    def prime_step(self, cfg, *, mode):
        return ST.cached_sharded_prime_step(cfg, mode=mode, tp=self.tp)

    def verify_step(self, cfg, *, mode, k, temperature):
        return ST.cached_sharded_verify_step(
            cfg, mode=mode, k=k, temperature=temperature, tp=self.tp)

    def propose_step(self, dcfg, *, mode, k):
        return ST.cached_sharded_draft_propose_step(
            dcfg, mode=mode, k=k, tp=self.tp)


class _Lane:
    """One admitted model on the engine: its compiled step set, its
    device cache(s), and its model-scoped host accounting (SlotPool,
    BlockPool, block-table mirror, dispatch buffers).

    A single-model engine is exactly one lane with ``tag=None`` — every
    legacy code path routes through it unchanged.  The multiplexed
    engine holds one lane per entry of ``Engine(models={...})``; no
    leaf of one lane's cache, block pool, or draft state is ever read
    by another lane's dispatches (decode-contract rule 8: per-lane
    pools make cross-model sharing structurally impossible, and the
    prefix hash chain is additionally seeded with the lane tag).

    Compiled steps come from the engine's :class:`ExecutorBackend`
    (whose providers sit on the process-wide memo in
    ``runtime.steps``), so a dedicated single-model engine and a
    multiplexed lane over the same config share one compilation —
    which is what keeps the differential test harness cheap."""

    def __init__(self, eng, tag: Optional[str], order: int,
                 cfg: ArchConfig, params, spec_k: int,
                 dcfg: Optional[ArchConfig], dparams):
        self.eng = eng
        self.tag = tag
        self.order = order                 # dense gid = order * S + sid
        self.cfg, self.params = cfg, params
        self.spec_k = spec_k               # 0 on lanes that can't draft
        self.dcfg, self.dparams = dcfg, dparams
        # hot-swap state: a retiring lane finishes its in-flight slots
        # but admission refuses new requests for it; epoch stamps when
        # the lane joined (0 = at engine construction)
        self.retiring = False
        self.epoch = 0
        be = eng.backend
        mode, temp = eng.mode, eng.temperature
        self.step = be.slot_step(cfg, mode=mode, temperature=temp)
        # encdec/vlm: the prime dispatch that writes a slot's cross-K/V
        # row (second slot-resident static operand) at admission, run
        # concurrently with other slots' decoding like chunked prefill
        self._prime_step = (be.prime_step(cfg, mode=mode)
                            if R.needs_prime(cfg) else None)
        # speculative steps: the target's wide verify step replaces the
        # fused 1-token step on every tick, the draft's propose step and
        # its own chunked catch-up steps feed it (draft state is a plain
        # contiguous cache — the draft never pages or shares blocks)
        if spec_k > 0:
            self._verify_step = be.verify_step(
                cfg, mode=mode, k=spec_k, temperature=temp)
            self._propose_step = be.propose_step(dcfg, mode=mode, k=spec_k)
        else:
            self._verify_step = self._propose_step = None
        self.reset()

    # -- per-serve runtime state ---------------------------------------

    def reset(self) -> None:
        """Fresh serving state: called at Engine construction and at the
        top of every ``serve`` (a serve never trusts a previous serve's
        device or host state)."""
        eng = self.eng
        S = eng.num_slots
        self.pool = SlotPool(S, max_seq=eng.max_seq, model=self.tag)
        self.cache = self._init_cache()
        self.tokens = np.zeros((S, 1), np.int32)
        self.index = np.zeros((S,), np.int32)
        self.spec = self.spec_k > 0
        self.draft_cache = (R.init_cache(self.dcfg, S, eng.max_seq)
                            if self.spec else None)
        self.krow = np.zeros((S,), np.int32)
        self.props = self.tok_mat = self.n_tok = None
        paged = eng.block_size is not None
        self.bpool = (BlockPool(eng.num_blocks, eng.block_size,
                                model=self.tag) if paged else None)
        self.tables_np = (np.zeros((S, eng.max_blocks), np.int32)
                          if paged else None)
        self.tables_dirty = False
        # per-tick dispatch scratch (rebuilt each tick by the core)
        self.active_mask = np.zeros((S,), bool)
        self.ready: List[int] = []
        self.torn: List[int] = []
        self.nxt = None

    # -- compiled-step plumbing ----------------------------------------

    def _init_cache(self):
        """The pooled device cache: contiguous slot rows, or (paged mode)
        physical KV blocks behind an all-trash block table."""
        eng = self.eng
        if eng.block_size:
            return R.init_paged_cache(self.cfg, eng.num_slots,
                                      eng.max_seq, eng.block_size,
                                      eng.num_blocks)
        return R.init_cache(self.cfg, eng.num_slots, eng.max_seq)

    def _chunk_step(self, chunk: int) -> Callable:
        """The compiled prefill step for one bucket size (memoized in
        ``runtime.steps`` — at most one compilation per (config, bucket)
        ever exists in the process)."""
        return self.eng.backend.chunk_step(self.cfg, mode=self.eng.mode,
                                           chunk=chunk)

    def _draft_chunk_step(self, chunk: int) -> Callable:
        """The draft model's compiled prefill step for one bucket size —
        how the engine teacher-forces committed tokens the draft cache
        has not consumed yet (admission, exact resume, full accepts)."""
        return self.eng.backend.chunk_step(self.dcfg, mode=self.eng.mode,
                                           chunk=chunk)

    def _fused(self, tokens, cache, index, active):
        args = (self.params, jnp.asarray(tokens), cache,
                jnp.asarray(index), jnp.asarray(active))
        if self.eng.temperature > 0.0:
            return self.step(*args, self.eng.rng)
        return self.step(*args)

    def _verify(self, tok_mat, cache, index, n_tok, active):
        args = (self.params, jnp.asarray(tok_mat), cache,
                jnp.asarray(index), jnp.asarray(n_tok),
                jnp.asarray(active))
        if self.eng.temperature > 0.0:
            return self._verify_step(*args, self.eng.rng)
        return self._verify_step(*args)

    # -- paged-mode admission helpers (host-side; docs/serving.md) -----

    def _prefix_keys(self, req: EngineRequest) -> Tuple:
        """Exact prefix hash chain, one key per FULL prompt block:
        ``key_j = (key_{j-1}, block_j_tokens)`` — nested tuples compared
        by value, so equal keys mean equal token prefixes (no hash
        collisions by construction).  Prime families seed the chain with
        the request's source bytes: their self-KV at any position depends
        on the cross-attended source, so two prefixes only share when
        source AND tokens match.  A tagged lane additionally seeds the
        chain with its model tag — the explicit fingerprint behind the
        no-cross-model-sharing rule (each lane's BlockPool is private
        anyway, so this is defense in depth, not the only wall)."""
        bs = self.eng.block_size
        key: Tuple = ()
        if self._prime_step is not None:
            src = np.asarray(req.source, np.float32)
            key = (src.shape, src.tobytes())
        if self.tag is not None:
            key = (("model", self.tag), key)
        keys = []
        for j in range(len(req.prompt) // bs):
            key = (key, tuple(req.prompt[j * bs:(j + 1) * bs]))
            keys.append(key)
        return tuple(keys)

    def _usable_hits(self, req: EngineRequest,
                     keys: Optional[Tuple] = None) -> int:
        """Leading prompt blocks already resident (registered by an
        earlier tenant).  Capped at ``(prompt-1) // bs``: the LAST prompt
        token always rides the fused step, and its KV write must land in
        a privately owned block, never a shared one."""
        if keys is None:
            keys = self._prefix_keys(req)
        cap = (len(req.prompt) - 1) // self.eng.block_size
        hits = 0
        for j in range(min(cap, len(keys))):
            if self.bpool.lookup(keys[j]) is None:
                break
            hits += 1
        return hits

    def _block_cost(self, req: EngineRequest) -> int:
        """Worst-case FRESH blocks this request claims if admitted now:
        ceil((prompt + max_new) / bs) minus currently shareable prefix
        blocks — what memory-aware admission prices against the pool."""
        bs = self.eng.block_size
        need = -(-(len(req.prompt) + req.max_new_tokens) // bs)
        return need - self._usable_hits(req)


@dataclasses.dataclass
class DispatchOutcome:
    """Raw counters out of one :meth:`DispatchCore.run` — everything
    ``Engine.serve`` needs to assemble an ``EngineReport``, nothing
    aggregated (the core mechanizes; the engine reports)."""
    results: List[RequestResult]
    lanes: List["_Lane"]              # the serve's lane snapshot
    occupancy: List[int]
    occ_by_lane: Dict[str, List[int]]
    ticks: int = 0
    gen_tokens: int = 0
    emit_dispatches: int = 0
    admissions_while_busy: int = 0
    dropped: int = 0
    refused: int = 0
    preempted: int = 0
    failed: int = 0
    unfinished: int = 0
    dispatch_retries: int = 0
    nonfinite: int = 0
    torn_repaired: int = 0
    stuck_ticks: int = 0
    shared_hits: int = 0
    skipped_tokens: int = 0
    blocks_demanded: int = 0
    peak_used: int = 0
    util_sum: float = 0.0
    now: float = 0.0                  # engine-clock duration
    wall: float = 0.0                 # measured host time


class DispatchCore:
    """The tick loop: ingest -> (preempt) -> admit -> chunk prefill ->
    draft/propose -> fused or verify dispatch per lane -> host
    bookkeeping, repeated until the trace drains.  One instance per
    ``serve`` call; all cross-tick host state (stash, counters, clocks)
    is local to :meth:`run`.

    The core reads engine CONFIG (num_slots, block_size, policy, lanes,
    ...) but owns the serve-time MECHANISM — Engine never touches a
    slot, block, or stash directly."""

    def __init__(self, eng):
        self.eng = eng

    def run(self, reqs: List[EngineRequest], *,
            clock: str,
            tick_s: Union[float, Mapping, Callable[[int], float]],
            max_ticks: Optional[int],
            drop_missed_deadlines: bool,
            preemption: bool,
            fault_plan: Optional[FaultPlan],
            max_retries: int,
            control: Sequence[Tuple[float, Callable]] = ()
            ) -> DispatchOutcome:
        eng = self.eng
        by_rid = {r.rid: r for r in reqs}
        S = eng.num_slots
        lanes = list(eng.lanes.values())      # index == lane.order
        for ln in lanes:
            ln.reset()
        # hot-swap control schedule: (time_s, fn(engine)) ops executed at
        # tick boundaries once the clock passes their time — how a live
        # serve admits or retires a lane (engine.admit_model /
        # engine.retire_model) without draining the others
        ctl = sorted(control, key=lambda c: c[0])
        ctl_i = 0
        sched = SlotScheduler(eng.policy)
        results: List[RequestResult] = []
        occupancy: List[int] = []
        occ_by_lane: Dict[str, List[int]] = (
            {ln.tag: [] for ln in lanes} if eng.multi else {})
        admissions_while_busy = 0
        dropped = 0
        refused = 0
        ticks = 0
        gen_tokens = 0
        # a row-tick that commits >= 1 token is one "emitting dispatch":
        # accepted_per_dispatch = gen_tokens / emit_dispatches is exactly
        # 1.0 without speculation and the mean accepted+bonus run length
        # with it — the honest denominator for speculative throughput
        emit_dispatches = 0
        # overload robustness state: stashed progress of preempted
        # requests (rid -> _Stash) and the fault/recovery counters
        stash: Dict[int, _Stash] = {}
        preempted = failed = unfinished = 0
        dispatch_retries = nonfinite = torn_repaired = 0
        wd = StepWatchdog(name=eng.name) if clock == "wall" else None
        # paged-mode state lives per lane (lane.bpool / lane.tables_np);
        # the aggregate counters below span lanes
        paged = eng.block_size is not None
        shared_hits = 0
        skipped_tokens = 0
        blocks_demanded = 0
        peak_used = 0
        util_sum = 0.0
        # per-lane tick pricing: a Mapping tick_s charges each tick the
        # sum of its DISPATCHED lanes' per-lane service times, so a
        # heavy lane's dispatch is priced honestly when lanes differ
        lane_priced = isinstance(tick_s, Mapping)

        def total_active() -> int:
            return sum(ln.pool.active_count for ln in lanes)

        def _register_blocks(ln, st) -> None:
            # publish each prompt block for prefix sharing the moment the
            # slot's frontier passes its end (its KV writes are already
            # issued in dispatch order, so any later gather sees them)
            while (st.registered < len(st.prompt_keys)
                   and st.pos >= (st.registered + 1) * eng.block_size):
                ln.bpool.register(st.prompt_keys[st.registered],
                                  st.block_table[st.registered])
                st.registered += 1

        def _release_blocks(ln, st) -> None:
            for bid in st.block_table:
                ln.bpool.release(bid)
            st.block_table, st.prompt_keys, st.registered = None, (), 0
            ln.tables_np[st.sid, :] = 0       # retired row scatters to trash
            ln.tables_dirty = True

        def _eff_req(req: EngineRequest) -> EngineRequest:
            """The request as (re-)admission sees it: a preempted request
            resumes with its stashed tokens appended to the prompt
            (teacher-forced through prefill — the exact-resume mechanism)
            and its token budget reduced by the same count, so its total
            cache claim is invariant under preemption."""
            s = stash.get(req.rid)
            if s is None or not s.generated:
                return req
            return dataclasses.replace(
                req, prompt=req.prompt + tuple(s.generated),
                max_new_tokens=req.max_new_tokens - len(s.generated))

        def _block_cost(req: EngineRequest) -> int:
            ln_c = eng.lanes.get(getattr(req, "model", None))
            return (ln_c._block_cost(_eff_req(req))
                    if ln_c is not None else 0)

        def _preempt(ln, st) -> None:
            """Evict a live slot with exact-resume semantics: release its
            blocks, stash host progress, requeue the original request.
            No device state survives — resume rebuilds it all."""
            nonlocal preempted
            preempted += 1
            rid = st.rid                  # pool.free() scrubs it to -1
            stash[rid] = _Stash(
                generated=list(st.generated or []),
                first_token_s=st.first_token_s, admit_s=st.admit_s,
                preemptions=st.preemptions + 1, retries=st.retries)
            if paged and st.block_table is not None:
                _release_blocks(ln, st)
            ln.pool.free(st.sid)
            ln.index[st.sid] = 0
            ln.tokens[st.sid, 0] = 0
            sched.push(by_rid[rid])

        def _fail(ln, st) -> None:
            """Retire a slot fault recovery gave up on (typed status)."""
            nonlocal failed
            failed += 1
            results.append(RequestResult(
                rid=st.rid, tokens=list(st.generated or []),
                arrival_s=st.arrival_s, admit_s=st.admit_s,
                first_token_s=st.first_token_s, finish_s=now,
                slot=st.sid, status="failed", priority=st.priority,
                preemptions=st.preemptions, deadline_s=st.deadline_s,
                model=ln.tag))
            if paged and st.block_table is not None:
                _release_blocks(ln, st)
            ln.pool.free(st.sid)
            ln.index[st.sid] = 0
            ln.tokens[st.sid, 0] = 0

        i, now = 0, 0.0
        t0 = time.perf_counter()
        limit = max_ticks if max_ticks is not None else \
            (sum(len(r.prompt) + r.max_new_tokens for r in reqs) + 16) * 4

        with warnings.catch_warnings():
            # CPU backends warn that donated buffers were not usable
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            while (i < len(reqs) or sched.pending or total_active()
                   or ctl_i < len(ctl)):
                # 0) hot-swap control: run every op the clock has passed;
                #    each may admit or retire a lane, so refresh the lane
                #    snapshot (append-only during a serve — gid mapping
                #    `lanes[g // S]` stays index == order)
                while ctl_i < len(ctl) and ctl[ctl_i][0] <= now:
                    ctl[ctl_i][1](eng)
                    ctl_i += 1
                    lanes = list(eng.lanes.values())
                    if eng.multi:
                        for ln in lanes:
                            occ_by_lane.setdefault(
                                ln.tag, [0] * len(occupancy))
                if (i >= len(reqs) and not sched.pending
                        and not total_active()):
                    if ctl_i >= len(ctl):
                        break
                    now = max(now, ctl[ctl_i][0])
                    continue
                # 1) ingest everything that has arrived by `now`
                while i < len(reqs) and reqs[i].arrival_s <= now:
                    sched.push(reqs[i])
                    i += 1
                next_arrival = reqs[i].arrival_s if i < len(reqs) else None
                # lanes dispatched this tick (Mapping tick_s pricing)
                tick_lanes = set()
                # 2) admit into free slot leases — mid-flight, no drain
                #    barrier; `num_slots` caps the TOTAL across lanes
                generating = any(s.active and not s.in_prefill
                                 for ln in lanes for s in ln.pool.slots)
                if preemption and sched.pending:
                    # resource pressure + a strictly-higher-class head:
                    # evict the lowest-class generating slot (latest
                    # deadline first) until the head fits or no victim of
                    # lower class remains — equal class never preempts,
                    # so batch can't thrash batch.  Slot pressure frees a
                    # LEASE, so victims come from any lane; pure block
                    # pressure only helps if the victim is in the head's
                    # own lane (block pools are lane-private, rule 8).
                    head = sched.pending[0]
                    lane_h = eng.lanes.get(getattr(head, "model", None))
                    if lane_h is not None and not lane_h.retiring:
                        hrank = bt.priority_rank(
                            getattr(head, "priority",
                                    bt.PRIORITY_CLASSES[0]))
                        for _ in range(S * len(lanes)):
                            slot_pressed = total_active() >= S
                            block_pressed = (
                                paged and lane_h._block_cost(_eff_req(head))
                                > lane_h.bpool.free_blocks)
                            if not (slot_pressed or block_pressed):
                                break
                            vlanes = lanes if slot_pressed else [lane_h]
                            victims = [(ln, s) for ln in vlanes
                                       for s in ln.pool.active_slots()
                                       if bt.priority_rank(s.priority)
                                       > hrank]
                            if not victims:
                                break
                            ln_v, st_v = max(victims, key=lambda t: (
                                bt.priority_rank(t[1].priority),
                                t[1].deadline_s, t[0].order, t[1].sid))
                            _preempt(ln_v, st_v)
                quotas_on = bool(eng.policy.class_quotas)
                abc = None
                if quotas_on or eng.multi:
                    # quota denominators: on a multiplexed engine each
                    # active slot charges its (model, class) tuple AND the
                    # bare model and class keys, so quotas configured at
                    # any granularity meter correctly
                    abc = {}
                    for ln in lanes:
                        for s in ln.pool.active_slots():
                            if eng.multi:
                                for k in ((ln.tag, s.priority), ln.tag,
                                          s.priority):
                                    abc[k] = abc.get(k, 0) + 1
                            else:
                                abc[s.priority] = abc.get(s.priority, 0) + 1
                if paged:
                    budget = ({ln.tag: ln.bpool.free_blocks for ln in lanes}
                              if eng.multi else lanes[0].bpool.free_blocks)
                else:
                    budget = None
                cohort = sched.admit(
                    now, S - total_active(), next_arrival,
                    cost_fn=_block_cost if paged else None,
                    budget=budget,
                    active_by_class=abc,
                    key_fn=((lambda r: (getattr(r, "model", None),
                                        getattr(r, "priority",
                                                bt.PRIORITY_CLASSES[0])))
                            if eng.multi else None))
                admitted = 0
                for req in cohort:
                    ln = eng.lanes.get(getattr(req, "model", None))
                    s_res = stash.get(req.rid)
                    if ln is None or ln.retiring:
                        # hot-swap refusal: the lane was retired (or not
                        # yet admitted) — in-flight slots of a retiring
                        # lane keep running, but the lane-epoch check
                        # stops anything NEW from entering it
                        results.append(RequestResult(
                            rid=req.rid,
                            tokens=list(s_res.generated) if s_res else [],
                            arrival_s=req.arrival_s,
                            admit_s=s_res.admit_s if s_res else -1.0,
                            first_token_s=(s_res.first_token_s if s_res
                                           else -1.0),
                            finish_s=now, slot=-1, status="refused",
                            priority=req.priority,
                            preemptions=s_res.preemptions if s_res else 0,
                            deadline_s=req.deadline_s,
                            model=getattr(req, "model", None)))
                        stash.pop(req.rid, None)
                        refused += 1
                        continue
                    if drop_missed_deadlines and now > req.deadline_s:
                        # expired while queued: retire WITHOUT taking a
                        # slot — no prime or prefill dispatch is wasted
                        # on a request that is already dead (a preempted
                        # request keeps what it had generated)
                        results.append(RequestResult(
                            rid=req.rid,
                            tokens=list(s_res.generated) if s_res else [],
                            arrival_s=req.arrival_s,
                            admit_s=s_res.admit_s if s_res else now,
                            first_token_s=(s_res.first_token_s if s_res
                                           else -1.0),
                            finish_s=now, slot=-1, dropped=True,
                            status="dropped", priority=req.priority,
                            preemptions=s_res.preemptions if s_res else 0,
                            deadline_s=req.deadline_s, model=ln.tag))
                        stash.pop(req.rid, None)
                        dropped += 1
                        continue
                    admitted += 1
                    eff = _eff_req(req)
                    st = ln.pool.alloc(req.rid, eff.prompt,
                                       eff.max_new_tokens,
                                       now=now, arrival_s=req.arrival_s,
                                       deadline_s=req.deadline_s,
                                       priority=req.priority)
                    if s_res is not None:
                        # exact resume: the stashed tokens ride the prompt
                        # (teacher-forced), the generated list starts from
                        # them, and ttft/admit bookkeeping survives the
                        # eviction — alloc validated the INVARIANT claim
                        # eff.prompt + eff.max_new == original total
                        st.generated = list(s_res.generated)
                        st.max_new = req.max_new_tokens
                        st.first_token_s = s_res.first_token_s
                        st.admit_s = s_res.admit_s
                        st.preemptions = s_res.preemptions
                        st.retries = s_res.retries
                        del stash[req.rid]
                    ln.index[st.sid] = 0
                    if paged:
                        # build the slot's block table: ref every shared
                        # prefix block (their prefill chunks are skipped
                        # entirely), alloc the rest privately — the
                        # admission decision priced exactly this claim.
                        # Keys are model-fingerprinted (lane._prefix_keys)
                        # and looked up in the lane's OWN pool, so a hit
                        # can never cross models.
                        keys = ln._prefix_keys(eff)
                        hits = ln._usable_hits(eff, keys)
                        need = -(-(len(eff.prompt) + eff.max_new_tokens)
                                 // eng.block_size)
                        table = []
                        for j in range(hits):
                            bid = ln.bpool.lookup(keys[j])
                            ln.bpool.ref(bid)
                            table.append(bid)
                        for _ in range(need - hits):
                            table.append(ln.bpool.alloc())
                        st.block_table = table
                        st.prompt_keys = keys
                        st.registered = hits
                        st.pos = hits * eng.block_size
                        ln.index[st.sid] = st.pos
                        ln.tables_np[st.sid, :] = 0
                        ln.tables_np[st.sid, :len(table)] = table
                        ln.tables_dirty = True
                        shared_hits += hits
                        skipped_tokens += hits * eng.block_size
                        blocks_demanded += need
                    if ln._prime_step is not None:
                        # prime dispatch: write this slot's cross-K/V row
                        # (and its xlen frontier) once, concurrently with
                        # other slots' decoding — like a prefill chunk,
                        # its cost lands on this tick's clock (resume
                        # re-primes: reconstructed, never trusted)
                        src, n_valid = _padded_source(ln.cfg, req)
                        ln.cache = ln._prime_step(
                            ln.params, src, ln.cache,
                            jnp.asarray(st.sid, jnp.int32), n_valid)
                        tick_lanes.add(ln.tag)
                    left = len(st.prompt) - 1 - st.pos
                    if eng.prefill_chunk and left > 0:
                        # remaining prompt (all but the last token, minus
                        # any shared-prefix positions already resident)
                        # goes through the chunked prefill step; the last
                        # token rides the fused step (its sample = first
                        # output token)
                        st.chunk_left = left
                    else:
                        ln.tokens[st.sid, 0] = st.next_input()
                if generating:
                    admissions_while_busy += admitted
                if paged:
                    # push each dirty host table mirror before any
                    # dispatch this tick gathers or scatters through it
                    for ln in lanes:
                        if ln.tables_dirty:
                            ln.cache = dict(
                                ln.cache,
                                block_tables=jnp.asarray(ln.tables_np))
                            ln.tables_dirty = False
                # 3) idle: nothing active -> jump to the next event
                if total_active() == 0:
                    nxt_ctl = ctl[ctl_i][0] if ctl_i < len(ctl) else None
                    if (next_arrival is None and not sched.pending
                            and nxt_ctl is None):
                        break
                    if (next_arrival is None and not cohort
                            and nxt_ctl is None and sched.pending):
                        # this round consumed nothing from a non-empty
                        # queue, the pool is idle, and nothing is left to
                        # arrive: no future round can differ — surface
                        # the policy bug instead of spinning (the
                        # virtual-time twin of the run_virtual guard)
                        raise RuntimeError(
                            "admission declined a non-empty pending queue "
                            f"({len(sched.pending)} requests) with an idle "
                            "pool and no future arrival; check the policy "
                            "/ class_quotas configuration")
                    target = next_arrival if next_arrival is not None else now
                    if nxt_ctl is not None:
                        # a scheduled control op is an event too: never
                        # jump the idle clock past a pending hot-swap
                        target = (min(target, nxt_ctl)
                                  if next_arrival is not None else nxt_ctl)
                    if clock == "wall":
                        gap = target - (time.perf_counter() - t0)
                        if gap > 0:
                            time.sleep(min(gap, 0.05))
                        now = time.perf_counter() - t0
                    else:
                        now = max(now, target)
                    continue
                # 4) chunked prefill: each mid-prefill slot writes one
                #    bucketed chunk of teacher-forced prompt state in a
                #    single dispatch (admission-to-first-token shrinks
                #    from prompt_len ticks to ceil(prompt_len/chunk))
                for ln in lanes:
                    for st in ln.pool.active_slots():
                        if st.chunk_left <= 0:
                            continue
                        n = min(st.chunk_left, eng.prefill_chunk)
                        c = ST.bucket_batch(n)
                        buf = np.zeros((c,), np.int32)
                        buf[:n] = st.prompt[st.pos:st.pos + n]
                        ln.cache = ln._chunk_step(c)(
                            ln.params, jnp.asarray(buf), ln.cache,
                            jnp.asarray(st.sid, jnp.int32),
                            jnp.asarray(st.pos, jnp.int32),
                            jnp.asarray(n, jnp.int32))
                        st.pos += n
                        st.chunk_left -= n
                        ln.index[st.sid] = st.pos
                        tick_lanes.add(ln.tag)
                        if paged:
                            _register_blocks(ln, st)
                        if st.chunk_left == 0:
                            ln.tokens[st.sid, 0] = st.prompt[st.pos]
                # 4.5) speculative draft: catch each generating slot's
                #      draft cache up to its committed frontier (teacher-
                #      forced — this is also what rebuilds the draft after
                #      admission, preemption/resume, or slot reuse), then
                #      propose k greedy tokens per slot in ONE fused
                #      dispatch per speculating lane.  Draft dispatches
                #      see no fault injection: a wrong proposal can only
                #      be rejected.
                for ln in lanes:
                    if not ln.spec:
                        continue
                    ln.krow = np.zeros((S,), np.int32)
                    for st in ln.pool.active_slots():
                        if st.chunk_left > 0 or st.pos < len(st.prompt) - 1:
                            continue
                        k_row = min(ln.spec_k,
                                    st.max_new - len(st.generated) - 1,
                                    eng.max_seq - 1 - st.pos)
                        if k_row <= 0:
                            continue
                        ln.krow[st.sid] = k_row
                        P = len(st.prompt)
                        while st.draft_pos < st.pos:
                            n = min(st.pos - st.draft_pos, eng._draft_cap)
                            c = ST.bucket_batch(n)
                            buf = np.zeros((c,), np.int32)
                            for t in range(n):
                                p = st.draft_pos + t
                                buf[t] = (st.prompt[p] if p < P
                                          else st.generated[p - P])
                            ln.draft_cache = ln._draft_chunk_step(c)(
                                ln.dparams, jnp.asarray(buf),
                                ln.draft_cache,
                                jnp.asarray(st.sid, jnp.int32),
                                jnp.asarray(st.draft_pos, jnp.int32),
                                jnp.asarray(n, jnp.int32))
                            st.draft_pos += n
                    d_active = ln.krow > 0
                    if d_active.any():
                        d_index = np.array(
                            [s.draft_pos for s in ln.pool.slots], np.int32)
                        props, ln.draft_cache, _ = ln._propose_step(
                            ln.dparams, jnp.asarray(ln.tokens),
                            ln.draft_cache,
                            jnp.asarray(d_index), jnp.asarray(d_active))
                        ln.props = np.asarray(props)
                        tick_lanes.add(ln.tag)
                    else:
                        ln.props = np.zeros((S, ln.spec_k), np.int32)
                # 5) one fused slot-masked step PER LANE with live slots:
                #    every ready slot (not mid-chunk), one token — or,
                #    speculating, one wide verify dispatch scoring 1..k+1
                #    tokens per ready slot (same single compiled shape per
                #    lane whatever the mix).  Fault injection addresses
                #    slots by dense GLOBAL id (lane.order * S + sid) so a
                #    single-lane engine sees byte-identical sid streams.
                all_ready: List[int] = []      # global ids, lane-major
                for ln in lanes:
                    ln.active_mask = np.array(
                        [s.active and s.chunk_left == 0
                         for s in ln.pool.slots], bool)
                    ln.ready = [int(s) for s in np.where(ln.active_mask)[0]]
                    ln.torn = []
                    ln.nxt = None
                    all_ready.extend(ln.order * S + sid for sid in ln.ready)
                if fault_plan is not None and paged and all_ready:
                    # fault: tear the victim's DEVICE table row (zero ->
                    # all-trash) just before dispatch; the host mirror
                    # stays clean, which is exactly how the post-step
                    # audit knows what to rebuild
                    for g in fault_plan.torn_rows(ticks, all_ready):
                        lanes[g // S].torn.append(g % S)
                    for ln in lanes:
                        if ln.torn:
                            torn = ln.tables_np.copy()
                            for sid in ln.torn:
                                torn[sid, :] = 0
                            ln.cache = dict(ln.cache,
                                            block_tables=jnp.asarray(torn))
                            ln.tables_dirty = True  # clean mirror repushed
                if all_ready:
                    # resolve dispatch faults FIRST, over the union of
                    # ready global ids (the injected fault strikes the
                    # tick's dispatch sequence, whichever lane the culprit
                    # sits in), then run each lane's step exactly once
                    attempt = 0
                    while all_ready:
                        culprit = (fault_plan.dispatch_fault(
                            ticks, attempt, all_ready)
                            if fault_plan is not None else None)
                        if culprit is None:
                            break
                        # dispatch failed: charge the culprit's retry
                        # budget; past max_retries the request is retired
                        # as `failed` and the retry goes on without it —
                        # one poisoned slot never takes down the cohort
                        dispatch_retries += 1
                        attempt += 1
                        ln = lanes[culprit // S]
                        sid = culprit % S
                        st = ln.pool.slots[sid]
                        st.retries += 1
                        if st.retries > max_retries:
                            _fail(ln, st)
                            ln.active_mask[sid] = False
                            ln.ready.remove(sid)
                            all_ready.remove(culprit)
                for ln in lanes:
                    if not ln.ready:
                        continue
                    tick_lanes.add(ln.tag)
                    if ln.spec:
                        # per-row verify payload: the committed next input
                        # in column 0, the row's usable proposals after it
                        ln.tok_mat = np.zeros((S, ln.spec_k + 1), np.int32)
                        ln.tok_mat[:, 0] = ln.tokens[:, 0]
                        for sid in ln.ready:
                            kr = int(ln.krow[sid])
                            if kr > 0:
                                ln.tok_mat[sid, 1:1 + kr] = \
                                    ln.props[sid, :kr]
                        ln.n_tok = np.where(ln.active_mask, 1 + ln.krow,
                                            0).astype(np.int32)
                        nxt, ln.cache, new_index = ln._verify(
                            ln.tok_mat, ln.cache, ln.index, ln.n_tok,
                            ln.active_mask)
                    else:
                        nxt, ln.cache, new_index = ln._fused(
                            ln.tokens, ln.cache, ln.index, ln.active_mask)
                    ln.nxt = np.asarray(nxt)
                    ln.index = np.array(new_index)   # writable host copy
                if not all_ready and clock == "wall":
                    # charge chunk/prime time here
                    jax.block_until_ready([ln.cache for ln in lanes])
                if fault_plan is not None and all_ready:
                    # fault: poison chosen slots' logits — modelled at the
                    # guard's observable surface, the -1 sentinel the
                    # in-graph finite check emits for NaN/Inf rows
                    for g in fault_plan.nonfinite_slots(ticks, all_ready):
                        ln = lanes[g // S]
                        ln.nxt = np.array(ln.nxt)    # writable copy
                        ln.nxt[g % S] = -1
                ticks += 1
                tact = total_active()
                occupancy.append(tact)
                for t in occ_by_lane:
                    occ_by_lane[t].append(eng.lanes[t].pool.active_count)
                if paged:
                    used = sum(ln.bpool.used_blocks for ln in lanes)
                    peak_used = max(peak_used, used)
                    util_sum += used / max(
                        1, (eng.num_blocks - 1) * len(lanes))
                if clock == "wall":
                    # np.asarray(nxt) above already blocked on the step
                    prev = now
                    now = time.perf_counter() - t0
                    # stuck-tick watchdog: with static shapes, per-tick
                    # wall time is tight — a straggler means a sick
                    # host, not workload variance
                    msg = wd.record(now - prev)
                    if msg:
                        warnings.warn(f"engine tick {ticks}: {msg}",
                                      RuntimeWarning)
                elif lane_priced:
                    # every lane that dispatched anything this tick
                    # (chunk, prime, draft, fused or verify) contributes
                    # its configured service time; an admission-only tick
                    # with no dispatch charges the cheapest lane's time
                    # (the clock must still advance)
                    vals = [float(tick_s[t]) for t in sorted(
                        tick_lanes, key=lambda x: (x is None, x))]
                    now += (sum(vals) if vals
                            else min(float(v) for v in tick_s.values()))
                else:
                    dt = tick_s(tact) if callable(tick_s) else tick_s
                    now += dt
                # 6) host bookkeeping, lane by lane: teacher-force
                #    prefill, collect samples, retire finished slots for
                #    immediate lease reuse (by any lane)
                for ln in lanes:
                  for sid in ln.torn:
                    # the torn row sent this tick's K/V write to trash
                    # and sampled through garbage gathers: the slot's
                    # device state can no longer be trusted, so the
                    # audit repairs the table (clean mirror repush) and
                    # rebuilds the tenant from scratch via preemption —
                    # its output stays bit-for-bit (exact resume)
                    st = ln.pool.slots[sid]
                    if not st.active:
                        continue          # already retired by _fail
                    torn_repaired += 1
                    _preempt(ln, st)
                  for st in ln.pool.active_slots():
                    if st.sid in ln.torn:
                        continue
                    if drop_missed_deadlines and now > st.deadline_s:
                        # deadline miss — possibly mid-prefill, before
                        # any token: record with the first_token_s
                        # sentinel intact (ttft aggregates exclude it)
                        results.append(RequestResult(
                            rid=st.rid, tokens=list(st.generated),
                            arrival_s=st.arrival_s, admit_s=st.admit_s,
                            first_token_s=st.first_token_s, finish_s=now,
                            slot=st.sid, dropped=True, status="dropped",
                            priority=st.priority,
                            preemptions=st.preemptions,
                            deadline_s=st.deadline_s, model=ln.tag))
                        dropped += 1
                        if paged:
                            _release_blocks(ln, st)
                        ln.pool.free(st.sid)
                        continue
                    if st.chunk_left > 0:          # mid-chunk: no sample
                        continue
                    if not ln.spec:
                        st.pos += 1
                        if paged:
                            _register_blocks(ln, st)
                        if st.pos < len(st.prompt):    # still prefilling
                            ln.tokens[st.sid, 0] = st.prompt[st.pos]
                            continue
                        tok = int(ln.nxt[st.sid])
                        if tok < 0:
                            # the in-graph finite guard's sentinel: this
                            # slot's logits went NaN/Inf.  The sample is
                            # garbage and the cache row suspect — rebuild
                            # deterministically via preemption (a transient
                            # fault recomputes clean, bit-for-bit); a slot
                            # that keeps faulting exhausts its retry budget
                            # and is retired as `failed`
                            nonfinite += 1
                            st.retries += 1
                            if st.retries > max_retries:
                                _fail(ln, st)
                            else:
                                _preempt(ln, st)
                            continue
                        st.generated.append(tok)
                        gen_tokens += 1
                        emit_dispatches += 1
                        if st.first_token_s < 0:
                            st.first_token_s = now
                        if st.done():
                            results.append(RequestResult(
                                rid=st.rid, tokens=list(st.generated),
                                arrival_s=st.arrival_s, admit_s=st.admit_s,
                                first_token_s=st.first_token_s,
                                finish_s=now,
                                slot=st.sid, priority=st.priority,
                                preemptions=st.preemptions,
                                deadline_s=st.deadline_s, model=ln.tag))
                            if paged:
                                _release_blocks(ln, st)
                            ln.pool.free(st.sid)
                        else:
                            ln.tokens[st.sid, 0] = tok
                        continue
                    # speculative commit: walk the verified row, keeping
                    # the accepted prefix + the bonus sample, then REWIND
                    # the device index to the committed frontier — the
                    # rejected tail's KV writes die by overwrite-before-
                    # read (decode-contract rule 7)
                    nt = int(ln.n_tok[st.sid])
                    row = ln.nxt[st.sid]
                    if np.any(row[:nt] < 0):
                        # any sentinel in the fed range poisons the whole
                        # round: in-flight proposals are uncommitted state,
                        # so fault recovery rebuilds from the last COMMITTED
                        # token exactly as in the non-speculative engine
                        nonfinite += 1
                        st.retries += 1
                        if st.retries > max_retries:
                            _fail(ln, st)
                        else:
                            _preempt(ln, st)
                        continue
                    pos0 = st.pos
                    committed = 0
                    for j in range(nt):
                        st.pos += 1
                        if paged:
                            _register_blocks(ln, st)
                        if st.pos < len(st.prompt):    # still prefilling
                            ln.tokens[st.sid, 0] = st.prompt[st.pos]
                            break
                        tok = int(row[j])
                        st.generated.append(tok)
                        gen_tokens += 1
                        committed += 1
                        if st.first_token_s < 0:
                            st.first_token_s = now
                        if st.done() or (j + 1 < nt
                                         and tok != int(ln.tok_mat[st.sid,
                                                                   j + 1])):
                            break
                    ln.index[st.sid] = st.pos  # the rewind past rejections
                    if committed:
                        emit_dispatches += 1
                        if ln.krow[st.sid] > 0:
                            # the draft consumed [f, d_1..d_{k-1}]; the
                            # committed-valid prefix of that is 1 + the
                            # accepted count (capped at k-1): gap 0 after
                            # a partial accept, 1 after a full accept
                            st.draft_pos = pos0 + 1 + min(
                                committed - 1, ln.spec_k - 1)
                    if st.done():
                        results.append(RequestResult(
                            rid=st.rid, tokens=list(st.generated),
                            arrival_s=st.arrival_s, admit_s=st.admit_s,
                            first_token_s=st.first_token_s, finish_s=now,
                            slot=st.sid, priority=st.priority,
                            preemptions=st.preemptions,
                            deadline_s=st.deadline_s, model=ln.tag))
                        if paged:
                            _release_blocks(ln, st)
                        ln.pool.free(st.sid)
                    elif committed:
                        ln.tokens[st.sid, 0] = st.generated[-1]
                if ticks > limit:
                    # the cap exists to bound a stuck run; hitting it is
                    # an overload outcome, not a crash — retire everything
                    # still in flight (and everything that never got in)
                    # with the typed `unfinished` status and report it
                    warnings.warn(
                        f"engine hit the {limit}-tick cap with "
                        f"{total_active()} active, "
                        f"{len(sched.pending)} pending and "
                        f"{len(reqs) - i} unarrived requests; retiring "
                        "them as 'unfinished'", RuntimeWarning)
                    for ln in lanes:
                        for st in ln.pool.active_slots():
                            unfinished += 1
                            results.append(RequestResult(
                                rid=st.rid, tokens=list(st.generated or []),
                                arrival_s=st.arrival_s, admit_s=st.admit_s,
                                first_token_s=st.first_token_s,
                                finish_s=now,
                                slot=st.sid, status="unfinished",
                                priority=st.priority,
                                preemptions=st.preemptions,
                                deadline_s=st.deadline_s, model=ln.tag))
                            if paged:
                                _release_blocks(ln, st)
                            ln.pool.free(st.sid)
                    for req in list(sched.pending) + reqs[i:]:
                        s_res = stash.pop(req.rid, None)
                        unfinished += 1
                        results.append(RequestResult(
                            rid=req.rid,
                            tokens=list(s_res.generated) if s_res else [],
                            arrival_s=req.arrival_s,
                            admit_s=s_res.admit_s if s_res else -1.0,
                            first_token_s=(s_res.first_token_s if s_res
                                           else -1.0),
                            finish_s=now, slot=-1, status="unfinished",
                            priority=req.priority,
                            preemptions=s_res.preemptions if s_res else 0,
                            deadline_s=req.deadline_s,
                            model=getattr(req, "model", None)))
                    sched.pending.clear()
                    i = len(reqs)
                    break

        return DispatchOutcome(
            results=results, lanes=lanes, occupancy=occupancy,
            occ_by_lane=occ_by_lane, ticks=ticks, gen_tokens=gen_tokens,
            emit_dispatches=emit_dispatches,
            admissions_while_busy=admissions_while_busy,
            dropped=dropped, refused=refused, preempted=preempted,
            failed=failed, unfinished=unfinished,
            dispatch_retries=dispatch_retries, nonfinite=nonfinite,
            torn_repaired=torn_repaired,
            stuck_ticks=wd.slow_steps if wd is not None else 0,
            shared_hits=shared_hits, skipped_tokens=skipped_tokens,
            blocks_demanded=blocks_demanded, peak_used=peak_used,
            util_sum=util_sum, now=now,
            wall=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# source-embedding validation / padding (prime families)
# ---------------------------------------------------------------------------

def _validate_source(cfg: ArchConfig, req: EngineRequest) -> np.ndarray:
    """Host-side shape/length checks only (no device array is built —
    ``serve`` validates the whole trace up front before admitting
    anything, and builds the padded array once, at admission)."""
    smax = R.source_len(cfg)
    if req.source is None:
        raise ValueError(
            f"request {req.rid}: {cfg.family!r} serves against per-request "
            f"source embeddings; EngineRequest.source must be "
            f"(src_len <= {smax}, {cfg.d_model})")
    src = np.asarray(req.source, np.float32)
    if src.ndim != 2 or src.shape[1] != cfg.d_model:
        raise ValueError(
            f"request {req.rid}: source must be (src_len, {cfg.d_model}), "
            f"got {src.shape}")
    n = src.shape[0]
    if not 0 < n <= smax:
        raise ValueError(
            f"request {req.rid}: source length {n} outside (0, {smax}]")
    return src


def _padded_source(cfg: ArchConfig, req: EngineRequest):
    """One request's source embeddings padded to the static prime shape:
    (1, source_len(cfg), d_model) bf16 plus the () int32 count of real
    positions.  Shared by the engine's prime dispatch and the sequential
    reference, so both prime with byte-identical inputs — the pad is
    masked behind the row's xlen frontier at decode time."""
    src = _validate_source(cfg, req)
    n = src.shape[0]
    buf = np.zeros((1, R.source_len(cfg), cfg.d_model), np.float32)
    buf[0, :n] = src
    return (jnp.asarray(buf, jnp.bfloat16),
            jnp.asarray(n, jnp.int32))
