"""Continuous-batching serving engine with a slot-based KV cache.

The paper's serving argument made live: deterministic execution under a
fixed p99 deadline beats throughput-first designs (Table 4).  The engine
owns a fixed pool of KV-cache *slots* (static ``num_slots x max_seq``
shapes, so there is exactly one compiled decode step and its latency is
predictable), admits arriving requests into free slots, advances every
active slot with ONE fused slot-masked decode step per tick, and retires
finished slots for immediate reuse — no drain barrier between request
generations.

Modules:
- ``slots``:     slot pool bookkeeping (host side, no jax),
- ``scheduler``: admission frontend over `core.batching.AdmissionPolicy`
                 (the same decision procedure the virtual-time simulator
                 uses — property-tested identical),
- ``dispatch``:  the dispatch core — the per-lane tick loop, slot/block
                 accounting, stash/exact-resume and fault plumbing —
                 plus the ``ExecutorBackend`` seam (single-device or
                 tensor-parallel sharded executors),
- ``engine``:    policy + reporting over a backend, plus the sequential
                 reference decoder the parity tests compare against
                 bit-for-bit,
- ``router``:    the replica tier — N engines load-balanced by projected
                 slot occupancy behind the same admission policy.

``Engine(block_size=...)`` switches the positional KV leaves to a paged
layout: fixed-size physical blocks behind a per-slot block table
(``slots.BlockPool`` holds the free list / refcounts / prefix-hash
registry), with identical-prompt prefixes shared copy-on-extend and
admission priced in worst-case blocks instead of free slots alone.

Overload robustness (``faults`` + ``serve(preemption=...,
fault_plan=...)``): SLO-class admission with per-class slot quotas,
slot preemption with bit-for-bit exact resume, and a seeded
deterministic fault-injection harness with bounded per-slot recovery —
see "Overload & failure semantics" in ``docs/serving.md``.
"""
from repro.engine.dispatch import (DispatchCore, ExecutorBackend,
                                   ShardedExecutor, SingleDeviceExecutor)
from repro.engine.engine import (Engine, EngineReport, EngineRequest,
                                 RequestResult, reference_outputs,
                                 synthetic_requests)
from repro.engine.faults import FAULT_KINDS, Fault, FaultPlan
from repro.engine.router import ReplicaRouter, RouterReport
from repro.engine.scheduler import SlotScheduler
from repro.engine.slots import (BlockPool, RequestTooLong, SlotPool,
                                SlotState)

__all__ = [
    "BlockPool", "DispatchCore", "Engine", "EngineReport", "EngineRequest",
    "ExecutorBackend", "FAULT_KINDS", "Fault", "FaultPlan",
    "ReplicaRouter", "RequestResult", "RequestTooLong", "RouterReport",
    "ShardedExecutor", "SingleDeviceExecutor", "SlotPool", "SlotScheduler",
    "SlotState", "reference_outputs", "synthetic_requests",
]
