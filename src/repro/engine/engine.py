"""The continuous-batching engine: one fused slot-masked step per tick.

Execution model (Orca-style iteration-level scheduling, specialized to
the paper's static-shape discipline):

- The KV cache is a fixed pool of ``num_slots`` rows of ``max_seq``
  positions — ONE compiled decode step ever exists, whatever the request
  mix, so per-tick latency is deterministic (the Table 4 argument).
- Every tick advances EVERY ready slot by one token in one fused
  ``make_slot_decode_step`` call (active mask folded into sampling and
  index advance, cache donated).  A slot mid-prefill is teacher-forced
  its next prompt token; a slot mid-generation feeds back its last
  sample; the first sample after the final prompt token is the request's
  first output token.
- EVERY registry family serves through the same step: positional KV
  state isolates per row behind each slot's ``valid_len`` frontier,
  recurrent state (ssm/hybrid) is frozen for inactive rows and scrubbed
  on reuse by the families' reset-at-position-0 rule, and the
  encoder-conditioned families (encdec/vlm) decode against a second
  slot-resident static operand — per-request primed cross-attention K/V,
  written once at admission by a *prime dispatch* that runs the encoder
  or vision tower and scatters the pre-projected cross K/V (plus the
  row's ``xlen`` frontier) into the slot's row (docs/serving.md).
- With ``prefill_chunk=c``, a newly admitted slot's prompt (all but the
  last token) is written by a chunked prefill step — one dispatch per
  bucketed chunk, concurrent with other slots' decoding — so
  admission-to-first-token drops from ``P`` ticks to ``ceil((P-1)/c)``
  (the final chunk tick doubles as the slot's first fused tick).  The
  chunk step scans the SAME per-token decode step, so outputs stay
  bit-for-bit equal to the per-token path.
- ``temperature > 0`` samples per row with ``fold_in(rng, position)`` —
  the fused decode loop's key schedule made per-row, so sampling parity
  holds against the sequential reference beyond greedy.
- ``spec_k > 0`` turns every tick into draft-and-verify speculative
  decoding: a small draft model (a second checkpoint, or a truncated-
  layer view of the target's own params) proposes up to ``k`` greedy
  tokens per generating slot, and ONE wide verify dispatch
  (``make_verify_step``) scores all proposals across the pool at a
  single compiled shape, committing the accepted prefix plus the bonus
  sample and rewinding each row's index past the rejected tail.  The
  committed stream is bit-for-bit the non-speculative stream, greedy or
  sampled (the verify scan reuses the per-position key schedule).
- Admission consults the same ``core.batching.AdmissionPolicy`` as the
  virtual-time simulator; admitted requests take over free slots
  immediately — there is NO drain barrier: new requests prefill while
  older ones are mid-generation (``admissions_while_busy`` counts the
  overlap, and the engine test asserts it is nonzero).
- Retired slots return to the pool the same tick they finish; stale
  cache contents need no scrub because every read is masked at the
  slot's own frontier.

Since the dispatch-core split (docs/architecture.md), this module is
the POLICY + REPORTING layer: request validation, admission policy and
lane configuration, and ``EngineReport`` assembly.  The tick loop,
slot/block accounting, stash/exact-resume, and fault plumbing live in
``engine.dispatch.DispatchCore``; compiled steps reach the core
through an ``ExecutorBackend`` — the single-device step set by
default, or ``ShardedExecutor(tp=...)`` to run the same steps
tensor-parallel under ``shard_map`` (bit-identical, slot-axis
sharding).  ``engine.router.ReplicaRouter`` scales this out across N
engine replicas.

``reference_outputs`` is the sequential per-token loop (batch=1, same
decode math) the engine must match bit-for-bit under greedy sampling.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import batching as bt
from repro.core.qlinear import FP, QuantMode
from repro.engine.dispatch import (DispatchCore, EngineRequest,
                                   ExecutorBackend, RequestResult,
                                   ShardedExecutor, SingleDeviceExecutor,
                                   _Lane, _padded_source, _validate_source)
from repro.engine.faults import FaultPlan
from repro.engine.slots import RequestTooLong
from repro.models import registry as R
from repro.runtime import steps as ST


@dataclasses.dataclass
class EngineReport:
    results: List[RequestResult]
    ticks: int
    generated_tokens: int
    duration_s: float                 # engine-clock time (virtual or wall)
    wall_s: float                     # measured host time, always
    p99_latency_s: float
    tokens_per_s: float
    occupancy: List[int]              # active slots per tick
    mean_occupancy: float             # fraction of the pool in use
    admissions_while_busy: int        # requests admitted while some older
                                      # request was mid-generation
    num_slots: int
    mean_ttft_s: float = 0.0          # admission-to-first-token, mean
    p99_ttft_s: float = 0.0           # admission-to-first-token, p99
    prefill_chunk: Optional[int] = None
    dropped: int = 0                  # requests retired on deadline miss
    # paged KV cache (Engine(block_size=...)) memory accounting — all
    # defaults when the engine runs contiguous rows
    block_size: Optional[int] = None
    num_blocks: int = 0               # physical blocks incl. reserved trash
    kv_hbm_bytes: int = 0             # resident KV-cache bytes (all leaves)
    peak_blocks_used: int = 0         # high-water mark of held blocks
    mean_block_util: float = 0.0      # mean held / usable blocks, per tick
    shared_block_hits: int = 0        # prefix blocks reused at admission
    shared_hit_rate: float = 0.0      # hits / worst-case blocks demanded
    prefill_tokens_skipped: int = 0   # prompt tokens served from shared blocks
    effective_concurrency: float = 0.0  # mean active requests per tick
    # overload robustness (serve(preemption=..., fault_plan=...)):
    preempted: int = 0                # eviction events (exact resume each)
    failed: int = 0                   # requests retired by fault recovery
    unfinished: int = 0               # requests retired by the tick cap
    dispatch_retries: int = 0         # failed fused-step dispatch attempts
    nonfinite_samples: int = 0        # sentinel tokens caught by the guard
    torn_rows_repaired: int = 0       # block-table rows audited + rebuilt
    stuck_ticks: int = 0              # wall-clock stragglers (watchdog)
    leaked_blocks: int = 0            # pool deficit at drain (must be 0)
    # hot-swap (Engine.retire_model / serve(control=...)): requests whose
    # lane was retired (or never admitted) before they could enter it
    refused: int = 0
    # per-SLO-class tails + the honest metric at scale: goodput counts
    # only completed requests that met their deadline
    class_p99_latency_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    class_mean_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    class_p99_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    goodput_tokens_per_s: float = 0.0
    slo_attainment: float = 0.0       # ok-and-on-time / all requests
    # speculative decoding (Engine(spec_k=..., draft=...|draft_layers=...)):
    spec_k: int = 0                   # proposal depth (0 = not speculating)
    accepted_per_dispatch: float = 0.0  # committed tokens per emitting
                                        # row-tick — exactly 1.0 without
                                        # speculation, the mean accepted+
                                        # bonus run length with it
    latency_per_token_s: float = 0.0  # mean over ok requests of
                                      # latency_s / emitted tokens
    # multi-model multiplexing (Engine(models={...})): per-model tails,
    # goodput and occupancy.  Empty on a single-model engine.  Per-model
    # occupancy is each lane's active slots over the SHARED lease budget
    # (num_slots), so the per-model fractions sum to mean_occupancy.
    model_p99_latency_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_mean_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_p99_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_goodput_tokens_per_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_mean_occupancy: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_occupancy: Dict[str, List[int]] = dataclasses.field(
        default_factory=dict)        # per-tick active slots per lane

    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.tokens for r in self.results}

    def outputs_for(self, model: Optional[str]) -> Dict[int, List[int]]:
        """One model lane's outputs — what the differential harness
        compares against a dedicated single-model engine."""
        return {r.rid: r.tokens for r in self.results if r.model == model}


class Engine:
    """Continuous-batching serving engine over a slot-based KV cache.

    Single-model (the legacy form): ``Engine(cfg, params, ...)`` — one
    model lane tagged ``None``, every request untagged.

    Multi-model multiplexing: ``Engine(models={tag: (cfg, params)},
    ...)`` — one lane per admitted model, each with its own compiled
    step set, device cache, slot pool, and (paged mode) block pool.
    Requests carry ``EngineRequest.model`` naming their lane; the tick
    loop interleaves per-lane fused dispatches, and the ``num_slots``
    lease budget caps TOTAL active slots across lanes (each lane's pool
    holds ``num_slots`` rows so any lane may hold the whole budget —
    one compiled batch shape per lane, dynamic leasing between them).
    Admission meters ``(model, class)`` quota keys through the same
    ``AdmissionPolicy``; see docs/serving.md, multi-model multiplexing.

    ``backend`` selects the executor the dispatch core runs compiled
    steps through: the default :class:`SingleDeviceExecutor`, or
    :class:`ShardedExecutor` for tensor-parallel slot-axis sharding
    (bit-identical outputs; docs/serving.md, "Scaling out").  ``name``
    labels this engine in straggler warnings and router rollups.
    """

    def __init__(self, cfg: Optional[ArchConfig] = None, params=None, *,
                 models: Optional[Dict[str, Tuple[ArchConfig, dict]]] = None,
                 mode: QuantMode = FP,
                 num_slots: int = 8, max_seq: int = 64,
                 policy: Optional[bt.AdmissionPolicy] = None,
                 prefill_chunk: Optional[int] = None,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 temperature: float = 0.0, rng=None,
                 spec_k: int = 0,
                 draft: Optional[Tuple[ArchConfig, dict]] = None,
                 draft_layers: Optional[int] = None,
                 backend: Optional[ExecutorBackend] = None,
                 name: Optional[str] = None):
        if (models is None) == (cfg is None):
            raise ValueError("exactly one of Engine(cfg, params) or "
                             "Engine(models={tag: (cfg, params)})")
        if temperature > 0.0 and rng is None:
            raise ValueError("temperature sampling needs an rng key: "
                             "Engine(..., temperature=t, rng=key)")
        # speculative decoding: spec_k > 0 turns every generation tick
        # into draft-propose (k greedy tokens from the draft model) +
        # one wide verify dispatch on the target; the committed output
        # is bit-for-bit the non-speculative output (docs/serving.md)
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k == 0 and (draft is not None or draft_layers is not None):
            raise ValueError("a draft model needs spec_k >= 1: "
                             "Engine(..., spec_k=k, draft=... or "
                             "draft_layers=...)")
        self.multi = models is not None
        if self.multi:
            if not models:
                raise ValueError("models must name at least one "
                                 "(cfg, params) lane")
            for tag in models:
                if not isinstance(tag, str) or not tag:
                    raise ValueError(
                        f"model tag must be a non-empty string, got {tag!r}")
            if spec_k > 0 and draft is not None:
                raise ValueError(
                    "a multiplexed engine cannot take one explicit "
                    "draft=(cfg, params) for every lane (vocabs differ); "
                    "use draft_layers=n — each supporting lane self-drafts")
            lane_cfgs = {t: cp for t, cp in models.items()}
        else:
            lane_cfgs = {None: (cfg, params)}
        if spec_k > 0 and not self.multi:
            if (draft is None) == (draft_layers is None):
                raise ValueError(
                    "speculative decoding needs exactly one of "
                    "draft=(cfg, params) or draft_layers=n "
                    "(truncated-layer self-draft)")
            if not R.supports_speculation(cfg):
                raise ValueError(
                    f"family {cfg.family!r} (window={cfg.window}) does not "
                    f"support speculative decoding: the target's decode "
                    f"state must be rewindable positional KV")
            if draft is not None:
                dcfg, dparams = draft
                if not R.supports_speculation(dcfg):
                    raise ValueError(
                        f"draft family {dcfg.family!r} "
                        f"(window={dcfg.window}) cannot draft: its decode "
                        f"state must be rewindable positional KV")
                if dcfg.vocab != cfg.vocab:
                    raise ValueError(
                        f"draft vocab {dcfg.vocab} != target vocab "
                        f"{cfg.vocab}: proposals would not be token-"
                        f"compatible")
        if spec_k > 0 and self.multi and draft_layers is None:
            raise ValueError("multiplexed speculation needs draft_layers=n")
        self.spec_k = spec_k
        self.mode = mode
        self.temperature, self.rng = temperature, rng
        self.name = name
        # the pool size IS the compiled batch shape: bucket it so the
        # engine's one decode step per lane sits on the static ladder;
        # the cache length rounds up to 16 so the slot dimension tiles
        # cleanly (paged mode additionally rounds to whole blocks)
        if num_blocks is not None and block_size is None:
            raise ValueError("num_blocks needs block_size: paged mode is "
                             "enabled by Engine(..., block_size=...)")
        if block_size is not None:
            if block_size < 1 or block_size & (block_size - 1):
                raise ValueError(
                    f"block_size must be a power of two, got {block_size}")
            for tag, (mcfg, _) in lane_cfgs.items():
                if not R.supports_paging(mcfg):
                    raise ValueError(
                        f"family {mcfg.family!r} (window={mcfg.window}"
                        f"{'' if tag is None else f', model {tag!r}'}) does "
                        f"not support the paged KV cache")
        self.num_slots = ST.bucket_batch(num_slots)
        align = max(16, block_size) if block_size else 16
        self.max_seq = max_seq + (-max_seq) % align
        self.block_size = block_size
        if block_size:
            self.max_blocks = self.max_seq // block_size
            # default pool (PER LANE): every slot can hold a full row
            # privately, +1 for the reserved trash block — byte-parity
            # with contiguous rows; pass a smaller num_blocks for
            # memory-bound admission
            self.num_blocks = (num_blocks if num_blocks is not None
                               else self.num_slots * self.max_blocks + 1)
            if self.num_blocks < 2:
                raise ValueError(f"num_blocks must be >= 2, "
                                 f"got {self.num_blocks}")
        else:
            self.max_blocks = 0
            self.num_blocks = 0
        # chunked prefill: cap rounds up to the same power-of-two ladder,
        # so chunk shapes and pool shapes share one bounded compile set
        self.prefill_chunk = (ST.bucket_batch(prefill_chunk)
                              if prefill_chunk else None)
        self.policy = policy or bt.AdmissionPolicy(
            lambda b: 0.0, max_batch=self.num_slots, max_wait_s=0.0)
        # the executor seam: every compiled step a lane holds comes from
        # this backend — swap it for ShardedExecutor(tp=...) and the
        # same engine serves tensor-parallel, bit-identically
        self.backend = backend if backend is not None \
            else SingleDeviceExecutor()
        self.backend.validate(self)
        # draft catch-up dispatch cap: per-tick gaps are <= 1 (a full
        # accept), but admission/resume rebuilds feed whole prompts
        self._draft_cap = (self.prefill_chunk or 16) if spec_k > 0 else 0
        self._draft_layers = draft_layers
        self._epoch = 0                  # bumps on every hot-swap admit
        # build the lanes: per-lane speculative resolution — a
        # multiplexed lane whose family cannot draft serves
        # non-speculatively ("where supported"), the single-model path
        # keeps its hard error above
        self.lanes: Dict[Optional[str], _Lane] = {}
        for order, (tag, (mcfg, mparams)) in enumerate(lane_cfgs.items()):
            lk = spec_k
            dcfg = dparams = None
            if spec_k > 0:
                if self.multi and not R.supports_speculation(mcfg):
                    lk = 0
                elif draft_layers is not None:
                    dcfg = R.draft_config(mcfg, draft_layers)
                    dparams = R.draft_params(mcfg, mparams, draft_layers)
                else:
                    dcfg, dparams = draft
            self.lanes[tag] = _Lane(self, tag, order, mcfg, mparams,
                                    lk, dcfg, dparams)
        # legacy aliases: the single-model engine's config/params (and
        # draft pair) remain reachable where old code expects them
        lane0 = next(iter(self.lanes.values()))
        self.cfg, self.params = lane0.cfg, lane0.params
        self.dcfg, self.dparams = lane0.dcfg, lane0.dparams

    # -- hot-swap: admit / retire a lane on a live engine ---------------

    def admit_model(self, tag: str, cfg: ArchConfig, params) -> None:
        """Admit a new model lane.  Legal mid-serve (through
        ``serve(control=...)``): the lane appends to the lane list with
        ``order = len(lanes)`` so fault gids and dispatch interleaving
        of existing lanes are untouched, and its fresh pools start
        empty — no other lane drains, stalls, or recompiles."""
        if not self.multi:
            raise ValueError("hot-swap needs a multiplexed engine: "
                             "Engine(models={...})")
        if not isinstance(tag, str) or not tag:
            raise ValueError(f"model tag must be a non-empty string, "
                             f"got {tag!r}")
        if tag in self.lanes:
            raise ValueError(f"model {tag!r} is already admitted")
        if self.block_size is not None and not R.supports_paging(cfg):
            raise ValueError(
                f"family {cfg.family!r} (window={cfg.window}, model "
                f"{tag!r}) does not support the paged KV cache")
        lk = self.spec_k
        dcfg = dparams = None
        if lk > 0:
            if not R.supports_speculation(cfg):
                lk = 0
            else:
                dcfg = R.draft_config(cfg, self._draft_layers)
                dparams = R.draft_params(cfg, params, self._draft_layers)
        lane = _Lane(self, tag, len(self.lanes), cfg, params,
                     lk, dcfg, dparams)
        self._epoch += 1
        lane.epoch = self._epoch
        self.lanes[tag] = lane

    def retire_model(self, tag: str) -> None:
        """Mark a lane retiring: its in-flight slots finish normally
        (their outputs stay bitwise what they would have been) but the
        lane-epoch check in admission refuses every NEW request for it
        with the typed ``refused`` status.  The drained lane is removed
        when the serve ends."""
        if tag not in self.lanes:
            raise ValueError(
                f"model {tag!r} is not admitted on this engine "
                f"(lanes: {[t for t in self.lanes]})")
        self.lanes[tag].retiring = True

    def warmup(self) -> None:
        """Trace + compile every lane's slot step (and, when chunked
        prefill is on, every reachable chunk bucket) on throwaway caches
        so a wall-clock ``serve`` charges its first tick to serving, not
        to compilation."""
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            S = self.num_slots
            for ln in self.lanes.values():
                cache = ln._init_cache()
                if ln._prime_step is not None:
                    cache = ln._prime_step(
                        ln.params,
                        jnp.zeros((1, R.source_len(ln.cfg),
                                   ln.cfg.d_model), jnp.bfloat16),
                        cache, jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32))
                if ln.spec_k > 0:
                    # speculative serve never dispatches the 1-token
                    # fused step: warm what it DOES run — verify,
                    # propose, and the draft's catch-up chunk buckets
                    _, cache, _ = ln._verify(
                        jnp.zeros((S, ln.spec_k + 1), jnp.int32), cache,
                        jnp.zeros((S,), jnp.int32),
                        jnp.zeros((S,), jnp.int32),
                        jnp.zeros((S,), bool))
                    dcache = R.init_cache(ln.dcfg, S, self.max_seq)
                    _, dcache, _ = ln._propose_step(
                        ln.dparams, jnp.zeros((S, 1), jnp.int32), dcache,
                        jnp.zeros((S,), jnp.int32), jnp.zeros((S,), bool))
                    c = 1
                    while c <= self._draft_cap:
                        dcache = ln._draft_chunk_step(c)(
                            ln.dparams, jnp.zeros((c,), jnp.int32), dcache,
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32))
                        c *= 2
                else:
                    _, cache, _ = ln._fused(
                        jnp.zeros((S, 1), jnp.int32), cache,
                        jnp.zeros((S,), jnp.int32),
                        jnp.zeros((S,), bool))
                if self.prefill_chunk:
                    # every reachable bucket: remainder chunks bucket to
                    # the smaller powers of two, and a cold compile
                    # mid-serve is exactly what this warmup exists to
                    # keep off the clock
                    c = 1
                    while c <= self.prefill_chunk:
                        cache = ln._chunk_step(c)(
                            ln.params, jnp.zeros((c,), jnp.int32), cache,
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32))
                        c *= 2

    def serve(self, requests: Sequence[EngineRequest], *,
              clock: str = "virtual",
              tick_s: Union[float, Mapping,
                            Callable[[int], float]] = 1e-3,
              max_ticks: Optional[int] = None,
              drop_missed_deadlines: bool = False,
              preemption: bool = False,
              fault_plan: Optional[FaultPlan] = None,
              max_retries: int = 3,
              control: Sequence[Tuple[float, Callable]] = ()
              ) -> EngineReport:
        """Serve a whole request trace; return per-request outputs and
        achieved latency/throughput/occupancy metrics.

        ``clock="virtual"``: time advances ``tick_s`` per tick (or
        ``tick_s(active_count)`` when callable) — fully deterministic,
        used by tests and the offline benchmark.  A *Mapping* ``tick_s``
        ({lane tag: seconds}) prices each tick as the SUM of the
        dispatched lanes' per-lane service times, so a multiplexed tick
        that dispatches a heavy lane costs honestly more than one that
        only advances a light lane.  ``clock="wall"``: time is the
        measured host clock — the live mode, where arrivals interleave
        with real step latency and a rolling-median watchdog flags
        stuck ticks (``EngineReport.stuck_ticks``).

        ``drop_missed_deadlines=True`` retires a slot the tick its
        deadline passes (possibly mid-prefill, before any token): its
        result is recorded with ``dropped=True``, whatever it generated,
        and — crucially — the ``first_token_s = -1.0`` sentinel, which
        the ttft aggregates below exclude.

        ``preemption=True`` lets admission-time pressure (no free slot,
        or a paged block claim the pool cannot cover) evict the active
        slot of strictly lower SLO class than the pending head — latest
        deadline first.  The victim's blocks are released, its host
        progress stashed, and it re-enters the pending queue; on
        re-admission its ``prompt + generated-so-far`` is teacher-forced
        through the chunked-prefill path, so the resumed output is
        bit-for-bit the never-preempted output (docs/serving.md).

        ``fault_plan`` injects a seeded :class:`FaultPlan`'s failures at
        their scheduled ticks; the recovery machinery (always on)
        retries failed dispatches, rebuilds slots that sample the
        non-finite sentinel or lose a torn block-table row, and retires
        a slot still faulting after ``max_retries`` recovery attempts
        with the typed ``failed`` status — one poisoned slot never takes
        down the cohort.

        ``control`` schedules hot-swap operations on the live serve: a
        sequence of ``(time_s, fn)`` pairs, each ``fn(engine)`` run at
        the first tick boundary past its time — typically closures over
        :meth:`admit_model` / :meth:`retire_model`.  Requests whose
        ``model`` tag is unknown at validation time are allowed through
        when a control schedule is present (a control op may admit the
        lane before they arrive); a request whose lane is retiring or
        still unknown when admission reaches it is refused with the
        typed ``refused`` status.

        On a multiplexed engine (``Engine(models={...})``) every
        request's ``model`` tag must name an admitted lane; the tick
        loop then interleaves one fused dispatch per lane with live
        slots, ``num_slots`` caps TOTAL active slots across lanes
        (dynamic leasing), and fault injection sees dense global slot
        ids (``lane.order * num_slots + sid``) so one seeded plan
        strikes across models deterministically.  All per-model state —
        cache, block pool, draft state, table mirror — stays
        lane-private (decode-contract rule 8).
        """
        if clock not in ("virtual", "wall"):
            raise ValueError(f"clock must be 'virtual' or 'wall': {clock!r}")
        if isinstance(tick_s, Mapping):
            if clock != "virtual":
                raise ValueError("per-lane tick_s mapping needs the "
                                 "virtual clock")
            missing = [t for t in self.lanes if t not in tick_s]
            if missing:
                raise ValueError(
                    f"per-lane tick_s must price every lane; missing "
                    f"{missing} (keys: {sorted(tick_s, key=repr)})")
        for t_ctl, fn_ctl in control:
            if not callable(fn_ctl):
                raise ValueError(
                    f"control entries must be (time_s, callable), got "
                    f"({t_ctl!r}, {fn_ctl!r})")
        for r in requests:
            mtag = getattr(r, "model", None)
            lane_r = self.lanes.get(mtag)
            if lane_r is None and not control:
                raise ValueError(
                    f"request {r.rid}: model {mtag!r} is not admitted on "
                    f"this engine (lanes: "
                    f"{[t for t in self.lanes]})")
            if r.max_new_tokens <= 0:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be positive "
                    f"(got {r.max_new_tokens})")
            need = len(r.prompt) + r.max_new_tokens
            if need > self.max_seq:
                raise RequestTooLong(
                    f"request {r.rid} needs {need} cache positions > "
                    f"max_seq={self.max_seq}")
            if self.block_size:
                nb = -(-need // self.block_size)
                if nb > self.num_blocks - 1:
                    # would wait forever even against an empty pool
                    raise RequestTooLong(
                        f"request {r.rid} needs {nb} KV blocks > "
                        f"{self.num_blocks - 1} usable in the pool")
            if lane_r is not None and lane_r._prime_step is not None:
                _validate_source(lane_r.cfg, r)
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        S = self.num_slots

        core = DispatchCore(self)
        out = core.run(reqs, clock=clock, tick_s=tick_s,
                       max_ticks=max_ticks,
                       drop_missed_deadlines=drop_missed_deadlines,
                       preemption=preemption, fault_plan=fault_plan,
                       max_retries=max_retries, control=control)
        lanes = out.lanes
        # hot-swap epilogue: a retired lane that has drained leaves the
        # engine now (its device cache is released with the lane); the
        # report below still covers it via the serve's lane snapshot
        for tag in [t for t, ln in self.lanes.items()
                    if ln.retiring and ln.pool.active_count == 0]:
            del self.lanes[tag]

        results = out.results
        results.sort(key=lambda r: r.rid)
        now, ticks = out.now, out.ticks
        occupancy = out.occupancy
        paged = self.block_size is not None
        lat = [r.latency_s for r in results if r.status == "ok"]
        # a request retired before emitting a token still carries the
        # first_token_s = -1.0 sentinel: it must never leak a negative
        # ttft into the aggregates
        ttft = [r.ttft_s for r in results if r.emitted]
        dur = max(now, 1e-12)
        kv_bytes = int(sum(x.size * x.dtype.itemsize
                           for ln in lanes
                           for x in jax.tree_util.tree_leaves(ln.cache)))
        # per-SLO-class tails + goodput: only a completed request that
        # met its deadline counts toward the honest metric at scale
        by_class: Dict[str, List[RequestResult]] = {}
        for r in results:
            by_class.setdefault(r.priority, []).append(r)
        cls_lat = {c: bt.p99([r.latency_s for r in rs if r.status == "ok"])
                   for c, rs in sorted(by_class.items())}
        cls_ttft = {c: [r.ttft_s for r in rs if r.emitted]
                    for c, rs in sorted(by_class.items())}
        good = [r for r in results
                if r.status == "ok" and r.finish_s <= r.deadline_s]
        good_tokens = sum(len(r.tokens) for r in good)
        lat_tok = [r.latency_s / len(r.tokens) for r in results
                   if r.status == "ok" and r.tokens]
        # per-model aggregates (multiplexed engines only; empty dicts on a
        # single-model engine keep its report byte-identical)
        mdl_lat: Dict[str, float] = {}
        mdl_ttft_mean: Dict[str, float] = {}
        mdl_ttft_p99: Dict[str, float] = {}
        mdl_goodput: Dict[str, float] = {}
        if self.multi:
            by_model: Dict[str, List[RequestResult]] = \
                {ln.tag: [] for ln in lanes}
            for r in results:
                by_model.setdefault(r.model, []).append(r)
            for m, rs in by_model.items():
                mdl_lat[m] = bt.p99(
                    [r.latency_s for r in rs if r.status == "ok"])
                ts = [r.ttft_s for r in rs if r.emitted]
                mdl_ttft_mean[m] = float(np.mean(ts)) if ts else 0.0
                mdl_ttft_p99[m] = bt.p99(ts)
                mdl_goodput[m] = sum(
                    len(r.tokens) for r in rs
                    if r.status == "ok" and r.finish_s <= r.deadline_s
                ) / dur
        return EngineReport(
            results=results, ticks=ticks,
            generated_tokens=out.gen_tokens,
            duration_s=now, wall_s=out.wall,
            p99_latency_s=bt.p99(lat),
            tokens_per_s=out.gen_tokens / dur,
            occupancy=occupancy,
            mean_occupancy=(sum(occupancy) / (len(occupancy) * S)
                            if occupancy else 0.0),
            admissions_while_busy=out.admissions_while_busy,
            num_slots=S,
            mean_ttft_s=float(np.mean(ttft)) if ttft else 0.0,
            p99_ttft_s=bt.p99(ttft),
            prefill_chunk=self.prefill_chunk,
            dropped=out.dropped,
            block_size=self.block_size,
            num_blocks=self.num_blocks,
            kv_hbm_bytes=kv_bytes,
            peak_blocks_used=out.peak_used,
            mean_block_util=(out.util_sum / ticks
                             if paged and ticks else 0.0),
            shared_block_hits=out.shared_hits,
            shared_hit_rate=(out.shared_hits / out.blocks_demanded
                             if out.blocks_demanded else 0.0),
            prefill_tokens_skipped=out.skipped_tokens,
            effective_concurrency=(sum(occupancy) / len(occupancy)
                                   if occupancy else 0.0),
            preempted=out.preempted,
            failed=out.failed,
            unfinished=out.unfinished,
            dispatch_retries=out.dispatch_retries,
            nonfinite_samples=out.nonfinite,
            torn_rows_repaired=out.torn_repaired,
            stuck_ticks=out.stuck_ticks,
            leaked_blocks=(sum((self.num_blocks - 1) - ln.bpool.free_blocks
                               for ln in lanes) if paged else 0),
            refused=out.refused,
            class_p99_latency_s=cls_lat,
            class_mean_ttft_s={c: (float(np.mean(ts)) if ts else 0.0)
                               for c, ts in cls_ttft.items()},
            class_p99_ttft_s={c: bt.p99(ts) for c, ts in cls_ttft.items()},
            goodput_tokens_per_s=good_tokens / dur,
            slo_attainment=(len(good) / len(results) if results else 0.0),
            spec_k=self.spec_k,
            accepted_per_dispatch=(out.gen_tokens / out.emit_dispatches
                                   if out.emit_dispatches else 0.0),
            latency_per_token_s=(float(np.mean(lat_tok))
                                 if lat_tok else 0.0),
            model_p99_latency_s=mdl_lat,
            model_mean_ttft_s=mdl_ttft_mean,
            model_p99_ttft_s=mdl_ttft_p99,
            model_goodput_tokens_per_s=mdl_goodput,
            model_mean_occupancy={
                t: (sum(v) / (len(v) * S) if v else 0.0)
                for t, v in out.occ_by_lane.items()},
            model_occupancy={t: list(v)
                             for t, v in out.occ_by_lane.items()})


# ---------------------------------------------------------------------------
# sequential reference + trace synthesis (shared by tests / serve / bench)
# ---------------------------------------------------------------------------

def reference_outputs(cfg: ArchConfig, params,
                      requests: Sequence[EngineRequest], *,
                      mode: QuantMode = FP, max_seq: int = 64,
                      temperature: float = 0.0, rng=None
                      ) -> Dict[int, List[int]]:
    """The sequential per-token reference loop: each request alone at
    batch=1, prompt teacher-forced a token at a time, then greedy
    generation — the bit-for-bit baseline the engine must reproduce.

    With ``temperature > 0`` sampling draws with the
    ``fold_in(rng, position)`` key schedule — the same schedule
    :func:`repro.runtime.steps.make_decode_loop` and the slot engine use
    (per-row there), so sampled outputs stay engine-comparable."""
    if temperature > 0.0 and rng is None:
        raise ValueError("temperature sampling needs an rng key")
    decode = jax.jit(ST.make_decode_step(cfg, mode=mode))
    # encdec/vlm: the same prime computation the engine dispatches, at a
    # pool of one slot (no donation: the reference is not a hot path)
    prime = (jax.jit(ST.make_prime_step(cfg, mode=mode))
             if R.needs_prime(cfg) else None)
    out: Dict[int, List[int]] = {}
    for r in sorted(requests, key=lambda x: x.rid):
        cache = R.init_cache(cfg, 1, max_seq)
        if prime is not None:
            src, n_valid = _padded_source(cfg, r)
            cache = prime(params, src, cache,
                          jnp.zeros((), jnp.int32), n_valid)
        tok = None
        gen: List[int] = []
        feed = list(r.prompt)
        pos = 0
        while len(gen) < r.max_new_tokens:
            cur = feed[pos] if pos < len(feed) else tok
            # prime families decode with a (1,)-vector index: the per-row
            # path is where the xlen frontier masks the padded source, and
            # the engine's slot rows take exactly that path
            idx = (jnp.asarray([pos], jnp.int32) if prime is not None
                   else jnp.asarray(pos, jnp.int32))
            logits, cache = decode(
                params,
                {"tokens": jnp.asarray([[cur]], jnp.int32),
                 "cache_index": idx}, cache)
            pos += 1
            if pos >= len(feed):
                if temperature > 0.0:
                    key = jax.random.fold_in(
                        rng, jnp.asarray(pos - 1, jnp.int32))
                    tok = int(ST.temperature_sample(logits, key,
                                                    temperature)[0])
                else:
                    tok = int(ST.greedy_sample(logits)[0])
                gen.append(tok)
        out[r.rid] = gen
    return out


def synthetic_requests(n: int, *, rate_per_s: float, vocab: int,
                       prompt_len: int = 4, max_new_tokens: int = 8,
                       deadline_s: float = float("inf"),
                       seed: int = 0,
                       shared_prefix_len: int = 0,
                       source_shape: Optional[Tuple[int, int]] = None,
                       priority: Union[str, Callable[[int], str]]
                       = "interactive",
                       arrival_process: Optional[
                           Callable[[int, float, int], Sequence[float]]]
                       = None,
                       model: Union[None, str, Callable[[int], str]]
                       = None) -> List[EngineRequest]:
    """Deterministic pseudo-Poisson request trace with synthetic prompts
    (derived from the rid, so any two runs see identical streams).

    ``shared_prefix_len=k`` makes the first ``k`` prompt tokens identical
    across ALL requests (a seed-derived "system prompt") with rid-seeded
    suffixes after it — the workload shape the paged engine's
    shared-prefix block reuse exists for.  The default 0 reproduces the
    fully rid-derived prompts exactly.

    ``source_shape=(source_len, d_model)`` additionally attaches
    per-request source embeddings for the prime families (encdec/vlm):
    rid-seeded gaussian frames/patches whose length varies across
    requests (full, -1, -2 cyclically), so a shared slot pool holds rows
    of different xlen frontiers at once.

    ``priority`` tags every request with an SLO class (a string) or a
    per-request one (a ``rid -> class`` callable).  ``arrival_process``
    replaces the pseudo-Poisson arrivals with a custom process — a
    callable ``(n, rate_per_s, seed) -> arrival times`` (sorted,
    seconds), e.g. the MMPP/bursty builders in ``benchmarks/traces.py``.

    ``model`` tags every request with a multiplexed engine's lane tag (a
    string) or a per-request one (a ``rid -> tag`` callable); the
    default ``None`` leaves requests untagged for single-model engines.
    The defaults reproduce today's traces byte-identically."""
    if not 0 <= shared_prefix_len <= prompt_len:
        raise ValueError(
            f"shared_prefix_len must be in [0, prompt_len={prompt_len}], "
            f"got {shared_prefix_len}")
    if arrival_process is None:
        arr = bt.poisson_arrivals(rate_per_s, n, 0.0, seed)
    else:
        times = list(arrival_process(n, rate_per_s, seed))
        if len(times) != n or any(b < a for a, b in zip(times, times[1:])):
            raise ValueError(
                f"arrival_process must return {n} sorted arrival times, "
                f"got {len(times)}")
        arr = [bt.Request(arrival_s=t, deadline_s=t, rid=rid)
               for rid, t in enumerate(times)]
    cls_of = priority if callable(priority) else (lambda rid: priority)
    mdl_of = model if callable(model) else (lambda rid: model)
    reqs = []
    for a in arr:
        prompt = tuple(
            (1 + (11 * j + 13 * seed) % (vocab - 1))
            if j < shared_prefix_len
            else (1 + (a.rid * 7 + 3 * j) % (vocab - 1))
            for j in range(prompt_len))
        source = None
        if source_shape is not None:
            smax, d = source_shape
            src_len = max(1, smax - a.rid % 3)
            g = np.random.default_rng((seed + 1) * 1_000_003 + a.rid)
            source = g.standard_normal((src_len, d)).astype(np.float32)
        reqs.append(EngineRequest(
            rid=a.rid, prompt=prompt, max_new_tokens=max_new_tokens,
            arrival_s=a.arrival_s,
            deadline_s=(a.arrival_s + deadline_s
                        if deadline_s != float("inf") else float("inf")),
            source=source, priority=cls_of(a.rid), model=mdl_of(a.rid)))
    return reqs
