"""The continuous-batching engine: one fused slot-masked step per tick.

Execution model (Orca-style iteration-level scheduling, specialized to
the paper's static-shape discipline):

- The KV cache is a fixed pool of ``num_slots`` rows of ``max_seq``
  positions — ONE compiled decode step ever exists, whatever the request
  mix, so per-tick latency is deterministic (the Table 4 argument).
- Every tick advances EVERY ready slot by one token in one fused
  ``make_slot_decode_step`` call (active mask folded into sampling and
  index advance, cache donated).  A slot mid-prefill is teacher-forced
  its next prompt token; a slot mid-generation feeds back its last
  sample; the first sample after the final prompt token is the request's
  first output token.
- EVERY registry family serves through the same step: positional KV
  state isolates per row behind each slot's ``valid_len`` frontier,
  recurrent state (ssm/hybrid) is frozen for inactive rows and scrubbed
  on reuse by the families' reset-at-position-0 rule, and the
  encoder-conditioned families (encdec/vlm) decode against a second
  slot-resident static operand — per-request primed cross-attention K/V,
  written once at admission by a *prime dispatch* that runs the encoder
  or vision tower and scatters the pre-projected cross K/V (plus the
  row's ``xlen`` frontier) into the slot's row (docs/serving.md).
- With ``prefill_chunk=c``, a newly admitted slot's prompt (all but the
  last token) is written by a chunked prefill step — one dispatch per
  bucketed chunk, concurrent with other slots' decoding — so
  admission-to-first-token drops from ``P`` ticks to ``ceil((P-1)/c)``
  (the final chunk tick doubles as the slot's first fused tick).  The
  chunk step scans the SAME per-token decode step, so outputs stay
  bit-for-bit equal to the per-token path.
- ``temperature > 0`` samples per row with ``fold_in(rng, position)`` —
  the fused decode loop's key schedule made per-row, so sampling parity
  holds against the sequential reference beyond greedy.
- ``spec_k > 0`` turns every tick into draft-and-verify speculative
  decoding: a small draft model (a second checkpoint, or a truncated-
  layer view of the target's own params) proposes up to ``k`` greedy
  tokens per generating slot, and ONE wide verify dispatch
  (``make_verify_step``) scores all proposals across the pool at a
  single compiled shape, committing the accepted prefix plus the bonus
  sample and rewinding each row's index past the rejected tail.  The
  committed stream is bit-for-bit the non-speculative stream, greedy or
  sampled (the verify scan reuses the per-position key schedule).
- Admission consults the same ``core.batching.AdmissionPolicy`` as the
  virtual-time simulator; admitted requests take over free slots
  immediately — there is NO drain barrier: new requests prefill while
  older ones are mid-generation (``admissions_while_busy`` counts the
  overlap, and the engine test asserts it is nonzero).
- Retired slots return to the pool the same tick they finish; stale
  cache contents need no scrub because every read is masked at the
  slot's own frontier.

``reference_outputs`` is the sequential per-token loop (batch=1, same
decode math) the engine must match bit-for-bit under greedy sampling.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import batching as bt
from repro.core.qlinear import FP, QuantMode
from repro.engine.faults import FaultPlan
from repro.engine.scheduler import SlotScheduler
from repro.engine.slots import BlockPool, RequestTooLong, SlotPool
from repro.runtime.watchdog import StepWatchdog
from repro.models import registry as R
from repro.runtime import steps as ST


@dataclasses.dataclass(frozen=True)
class EngineRequest:
    rid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_s: float = float("inf")
    # encdec/vlm: the request's source embeddings (src_len, d_model) —
    # encoder frames / vision patches a prime dispatch turns into the
    # slot's cross-K/V row at admission.  src_len may be shorter than the
    # static source length; the pad is masked behind the row's xlen.
    source: Optional[np.ndarray] = dataclasses.field(
        default=None, compare=False, repr=False)
    # SLO class (see core.batching.PRIORITY_CLASSES): admission orders
    # and sheds cohorts class-first, per-class slot quotas cap how many
    # slots a class may hold, and preemption only ever evicts a slot of
    # strictly lower class than the request it makes room for
    priority: str = "interactive"
    # multi-model multiplexing: which admitted model lane serves this
    # request (must name a tag of Engine(models={...}); None on a
    # single-model engine).  Quotas then meter (model, class) keys —
    # see docs/serving.md, multi-model multiplexing.
    model: Optional[str] = None


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: List[int]
    arrival_s: float
    admit_s: float
    first_token_s: float
    finish_s: float
    slot: int
    dropped: bool = False             # retired before completing (deadline)
    # typed outcome: "ok" (completed), "dropped" (deadline miss, mirrors
    # the bool), "failed" (retired by fault recovery after max_retries),
    # "unfinished" (still in flight when the tick cap hit)
    status: str = "ok"
    priority: str = "interactive"
    preemptions: int = 0              # times evicted + exactly resumed
    deadline_s: float = float("inf")
    model: Optional[str] = None       # serving model lane (None = single)

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def emitted(self) -> bool:
        """True once the request produced at least one token; ``ttft_s``
        is meaningless (the -1.0 sentinel) until then."""
        return self.first_token_s >= 0

    @property
    def ttft_s(self) -> float:
        """Admission-to-first-token: what chunked prefill shrinks.  Only
        defined when ``emitted`` — a request retired mid-prefill still
        carries the -1.0 sentinel, which aggregates must exclude."""
        return self.first_token_s - self.admit_s


@dataclasses.dataclass
class EngineReport:
    results: List[RequestResult]
    ticks: int
    generated_tokens: int
    duration_s: float                 # engine-clock time (virtual or wall)
    wall_s: float                     # measured host time, always
    p99_latency_s: float
    tokens_per_s: float
    occupancy: List[int]              # active slots per tick
    mean_occupancy: float             # fraction of the pool in use
    admissions_while_busy: int        # requests admitted while some older
                                      # request was mid-generation
    num_slots: int
    mean_ttft_s: float = 0.0          # admission-to-first-token, mean
    p99_ttft_s: float = 0.0           # admission-to-first-token, p99
    prefill_chunk: Optional[int] = None
    dropped: int = 0                  # requests retired on deadline miss
    # paged KV cache (Engine(block_size=...)) memory accounting — all
    # defaults when the engine runs contiguous rows
    block_size: Optional[int] = None
    num_blocks: int = 0               # physical blocks incl. reserved trash
    kv_hbm_bytes: int = 0             # resident KV-cache bytes (all leaves)
    peak_blocks_used: int = 0         # high-water mark of held blocks
    mean_block_util: float = 0.0      # mean held / usable blocks, per tick
    shared_block_hits: int = 0        # prefix blocks reused at admission
    shared_hit_rate: float = 0.0      # hits / worst-case blocks demanded
    prefill_tokens_skipped: int = 0   # prompt tokens served from shared blocks
    effective_concurrency: float = 0.0  # mean active requests per tick
    # overload robustness (serve(preemption=..., fault_plan=...)):
    preempted: int = 0                # eviction events (exact resume each)
    failed: int = 0                   # requests retired by fault recovery
    unfinished: int = 0               # requests retired by the tick cap
    dispatch_retries: int = 0         # failed fused-step dispatch attempts
    nonfinite_samples: int = 0        # sentinel tokens caught by the guard
    torn_rows_repaired: int = 0       # block-table rows audited + rebuilt
    stuck_ticks: int = 0              # wall-clock stragglers (watchdog)
    leaked_blocks: int = 0            # pool deficit at drain (must be 0)
    # per-SLO-class tails + the honest metric at scale: goodput counts
    # only completed requests that met their deadline
    class_p99_latency_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    class_mean_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    class_p99_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    goodput_tokens_per_s: float = 0.0
    slo_attainment: float = 0.0       # ok-and-on-time / all requests
    # speculative decoding (Engine(spec_k=..., draft=...|draft_layers=...)):
    spec_k: int = 0                   # proposal depth (0 = not speculating)
    accepted_per_dispatch: float = 0.0  # committed tokens per emitting
                                        # row-tick — exactly 1.0 without
                                        # speculation, the mean accepted+
                                        # bonus run length with it
    latency_per_token_s: float = 0.0  # mean over ok requests of
                                      # latency_s / emitted tokens
    # multi-model multiplexing (Engine(models={...})): per-model tails,
    # goodput and occupancy.  Empty on a single-model engine.  Per-model
    # occupancy is each lane's active slots over the SHARED lease budget
    # (num_slots), so the per-model fractions sum to mean_occupancy.
    model_p99_latency_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_mean_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_p99_ttft_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_goodput_tokens_per_s: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_mean_occupancy: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    model_occupancy: Dict[str, List[int]] = dataclasses.field(
        default_factory=dict)        # per-tick active slots per lane

    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.tokens for r in self.results}

    def outputs_for(self, model: Optional[str]) -> Dict[int, List[int]]:
        """One model lane's outputs — what the differential harness
        compares against a dedicated single-model engine."""
        return {r.rid: r.tokens for r in self.results if r.model == model}


@dataclasses.dataclass
class _Stash:
    """A preempted request's host-side progress, held between eviction
    and re-admission.  Device state is deliberately NOT kept: resume
    reconstructs every cache byte by teacher-forcing ``prompt +
    generated`` through the chunked-prefill path (decode is
    deterministic and the sampling key schedule is position-based, so
    the rebuilt run is bit-for-bit the never-preempted run) —
    "preempted state is reconstructed, never trusted"."""
    generated: List[int]
    first_token_s: float
    admit_s: float
    preemptions: int
    retries: int


class _Lane:
    """One admitted model on the engine: its compiled step set, its
    device cache(s), and its model-scoped host accounting (SlotPool,
    BlockPool, block-table mirror, dispatch buffers).

    A single-model engine is exactly one lane with ``tag=None`` — every
    legacy code path routes through it unchanged.  The multiplexed
    engine holds one lane per entry of ``Engine(models={...})``; no
    leaf of one lane's cache, block pool, or draft state is ever read
    by another lane's dispatches (decode-contract rule 8: per-lane
    pools make cross-model sharing structurally impossible, and the
    prefix hash chain is additionally seeded with the lane tag).

    Compiled steps come from the process-wide memo in
    ``runtime.steps`` (``cached_*``), so a dedicated single-model
    engine and a multiplexed lane over the same config share one
    compilation — which is what keeps the differential test harness
    cheap."""

    def __init__(self, eng: "Engine", tag: Optional[str], order: int,
                 cfg: ArchConfig, params, spec_k: int,
                 dcfg: Optional[ArchConfig], dparams):
        self.eng = eng
        self.tag = tag
        self.order = order                 # dense gid = order * S + sid
        self.cfg, self.params = cfg, params
        self.spec_k = spec_k               # 0 on lanes that can't draft
        self.dcfg, self.dparams = dcfg, dparams
        mode, temp = eng.mode, eng.temperature
        self.step = ST.cached_slot_decode_step(cfg, mode=mode,
                                               temperature=temp)
        # encdec/vlm: the prime dispatch that writes a slot's cross-K/V
        # row (second slot-resident static operand) at admission, run
        # concurrently with other slots' decoding like chunked prefill
        self._prime_step = (ST.cached_prime_step(cfg, mode=mode)
                            if R.needs_prime(cfg) else None)
        # speculative steps: the target's wide verify step replaces the
        # fused 1-token step on every tick, the draft's propose step and
        # its own chunked catch-up steps feed it (draft state is a plain
        # contiguous cache — the draft never pages or shares blocks)
        if spec_k > 0:
            self._verify_step = ST.cached_verify_step(
                cfg, mode=mode, k=spec_k, temperature=temp)
            self._propose_step = ST.cached_draft_propose_step(
                dcfg, mode=mode, k=spec_k)
        else:
            self._verify_step = self._propose_step = None
        self.reset()

    # -- per-serve runtime state ---------------------------------------

    def reset(self) -> None:
        """Fresh serving state: called at Engine construction and at the
        top of every ``serve`` (a serve never trusts a previous serve's
        device or host state)."""
        eng = self.eng
        S = eng.num_slots
        self.pool = SlotPool(S, max_seq=eng.max_seq, model=self.tag)
        self.cache = self._init_cache()
        self.tokens = np.zeros((S, 1), np.int32)
        self.index = np.zeros((S,), np.int32)
        self.spec = self.spec_k > 0
        self.draft_cache = (R.init_cache(self.dcfg, S, eng.max_seq)
                            if self.spec else None)
        self.krow = np.zeros((S,), np.int32)
        self.props = self.tok_mat = self.n_tok = None
        paged = eng.block_size is not None
        self.bpool = (BlockPool(eng.num_blocks, eng.block_size,
                                model=self.tag) if paged else None)
        self.tables_np = (np.zeros((S, eng.max_blocks), np.int32)
                          if paged else None)
        self.tables_dirty = False
        # per-tick dispatch scratch (rebuilt each tick by serve)
        self.active_mask = np.zeros((S,), bool)
        self.ready: List[int] = []
        self.torn: List[int] = []
        self.nxt = None

    # -- compiled-step plumbing ----------------------------------------

    def _init_cache(self):
        """The pooled device cache: contiguous slot rows, or (paged mode)
        physical KV blocks behind an all-trash block table."""
        eng = self.eng
        if eng.block_size:
            return R.init_paged_cache(self.cfg, eng.num_slots,
                                      eng.max_seq, eng.block_size,
                                      eng.num_blocks)
        return R.init_cache(self.cfg, eng.num_slots, eng.max_seq)

    def _chunk_step(self, chunk: int) -> Callable:
        """The compiled prefill step for one bucket size (memoized in
        ``runtime.steps`` — at most one compilation per (config, bucket)
        ever exists in the process)."""
        return ST.cached_prefill_chunk_step(self.cfg, mode=self.eng.mode,
                                            chunk=chunk)

    def _draft_chunk_step(self, chunk: int) -> Callable:
        """The draft model's compiled prefill step for one bucket size —
        how the engine teacher-forces committed tokens the draft cache
        has not consumed yet (admission, exact resume, full accepts)."""
        return ST.cached_prefill_chunk_step(self.dcfg, mode=self.eng.mode,
                                            chunk=chunk)

    def _fused(self, tokens, cache, index, active):
        args = (self.params, jnp.asarray(tokens), cache,
                jnp.asarray(index), jnp.asarray(active))
        if self.eng.temperature > 0.0:
            return self.step(*args, self.eng.rng)
        return self.step(*args)

    def _verify(self, tok_mat, cache, index, n_tok, active):
        args = (self.params, jnp.asarray(tok_mat), cache,
                jnp.asarray(index), jnp.asarray(n_tok),
                jnp.asarray(active))
        if self.eng.temperature > 0.0:
            return self._verify_step(*args, self.eng.rng)
        return self._verify_step(*args)

    # -- paged-mode admission helpers (host-side; docs/serving.md) -----

    def _prefix_keys(self, req: EngineRequest) -> Tuple:
        """Exact prefix hash chain, one key per FULL prompt block:
        ``key_j = (key_{j-1}, block_j_tokens)`` — nested tuples compared
        by value, so equal keys mean equal token prefixes (no hash
        collisions by construction).  Prime families seed the chain with
        the request's source bytes: their self-KV at any position depends
        on the cross-attended source, so two prefixes only share when
        source AND tokens match.  A tagged lane additionally seeds the
        chain with its model tag — the explicit fingerprint behind the
        no-cross-model-sharing rule (each lane's BlockPool is private
        anyway, so this is defense in depth, not the only wall)."""
        bs = self.eng.block_size
        key: Tuple = ()
        if self._prime_step is not None:
            src = np.asarray(req.source, np.float32)
            key = (src.shape, src.tobytes())
        if self.tag is not None:
            key = (("model", self.tag), key)
        keys = []
        for j in range(len(req.prompt) // bs):
            key = (key, tuple(req.prompt[j * bs:(j + 1) * bs]))
            keys.append(key)
        return tuple(keys)

    def _usable_hits(self, req: EngineRequest,
                     keys: Optional[Tuple] = None) -> int:
        """Leading prompt blocks already resident (registered by an
        earlier tenant).  Capped at ``(prompt-1) // bs``: the LAST prompt
        token always rides the fused step, and its KV write must land in
        a privately owned block, never a shared one."""
        if keys is None:
            keys = self._prefix_keys(req)
        cap = (len(req.prompt) - 1) // self.eng.block_size
        hits = 0
        for j in range(min(cap, len(keys))):
            if self.bpool.lookup(keys[j]) is None:
                break
            hits += 1
        return hits

    def _block_cost(self, req: EngineRequest) -> int:
        """Worst-case FRESH blocks this request claims if admitted now:
        ceil((prompt + max_new) / bs) minus currently shareable prefix
        blocks — what memory-aware admission prices against the pool."""
        bs = self.eng.block_size
        need = -(-(len(req.prompt) + req.max_new_tokens) // bs)
        return need - self._usable_hits(req)


class Engine:
    """Continuous-batching serving engine over a slot-based KV cache.

    Single-model (the legacy form): ``Engine(cfg, params, ...)`` — one
    model lane tagged ``None``, every request untagged.

    Multi-model multiplexing: ``Engine(models={tag: (cfg, params)},
    ...)`` — one lane per admitted model, each with its own compiled
    step set, device cache, slot pool, and (paged mode) block pool.
    Requests carry ``EngineRequest.model`` naming their lane; the tick
    loop interleaves per-lane fused dispatches, and the ``num_slots``
    lease budget caps TOTAL active slots across lanes (each lane's pool
    holds ``num_slots`` rows so any lane may hold the whole budget —
    one compiled batch shape per lane, dynamic leasing between them).
    Admission meters ``(model, class)`` quota keys through the same
    ``AdmissionPolicy``; see docs/serving.md, multi-model multiplexing.
    """

    def __init__(self, cfg: Optional[ArchConfig] = None, params=None, *,
                 models: Optional[Dict[str, Tuple[ArchConfig, dict]]] = None,
                 mode: QuantMode = FP,
                 num_slots: int = 8, max_seq: int = 64,
                 policy: Optional[bt.AdmissionPolicy] = None,
                 prefill_chunk: Optional[int] = None,
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 temperature: float = 0.0, rng=None,
                 spec_k: int = 0,
                 draft: Optional[Tuple[ArchConfig, dict]] = None,
                 draft_layers: Optional[int] = None):
        if (models is None) == (cfg is None):
            raise ValueError("exactly one of Engine(cfg, params) or "
                             "Engine(models={tag: (cfg, params)})")
        if temperature > 0.0 and rng is None:
            raise ValueError("temperature sampling needs an rng key: "
                             "Engine(..., temperature=t, rng=key)")
        # speculative decoding: spec_k > 0 turns every generation tick
        # into draft-propose (k greedy tokens from the draft model) +
        # one wide verify dispatch on the target; the committed output
        # is bit-for-bit the non-speculative output (docs/serving.md)
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k == 0 and (draft is not None or draft_layers is not None):
            raise ValueError("a draft model needs spec_k >= 1: "
                             "Engine(..., spec_k=k, draft=... or "
                             "draft_layers=...)")
        self.multi = models is not None
        if self.multi:
            if not models:
                raise ValueError("models must name at least one "
                                 "(cfg, params) lane")
            for tag in models:
                if not isinstance(tag, str) or not tag:
                    raise ValueError(
                        f"model tag must be a non-empty string, got {tag!r}")
            if spec_k > 0 and draft is not None:
                raise ValueError(
                    "a multiplexed engine cannot take one explicit "
                    "draft=(cfg, params) for every lane (vocabs differ); "
                    "use draft_layers=n — each supporting lane self-drafts")
            lane_cfgs = {t: cp for t, cp in models.items()}
        else:
            lane_cfgs = {None: (cfg, params)}
        if spec_k > 0 and not self.multi:
            if (draft is None) == (draft_layers is None):
                raise ValueError(
                    "speculative decoding needs exactly one of "
                    "draft=(cfg, params) or draft_layers=n "
                    "(truncated-layer self-draft)")
            if not R.supports_speculation(cfg):
                raise ValueError(
                    f"family {cfg.family!r} (window={cfg.window}) does not "
                    f"support speculative decoding: the target's decode "
                    f"state must be rewindable positional KV")
            if draft is not None:
                dcfg, dparams = draft
                if not R.supports_speculation(dcfg):
                    raise ValueError(
                        f"draft family {dcfg.family!r} "
                        f"(window={dcfg.window}) cannot draft: its decode "
                        f"state must be rewindable positional KV")
                if dcfg.vocab != cfg.vocab:
                    raise ValueError(
                        f"draft vocab {dcfg.vocab} != target vocab "
                        f"{cfg.vocab}: proposals would not be token-"
                        f"compatible")
        if spec_k > 0 and self.multi and draft_layers is None:
            raise ValueError("multiplexed speculation needs draft_layers=n")
        self.spec_k = spec_k
        self.mode = mode
        self.temperature, self.rng = temperature, rng
        # the pool size IS the compiled batch shape: bucket it so the
        # engine's one decode step per lane sits on the static ladder;
        # the cache length rounds up to 16 so the slot dimension tiles
        # cleanly (paged mode additionally rounds to whole blocks)
        if num_blocks is not None and block_size is None:
            raise ValueError("num_blocks needs block_size: paged mode is "
                             "enabled by Engine(..., block_size=...)")
        if block_size is not None:
            if block_size < 1 or block_size & (block_size - 1):
                raise ValueError(
                    f"block_size must be a power of two, got {block_size}")
            for tag, (mcfg, _) in lane_cfgs.items():
                if not R.supports_paging(mcfg):
                    raise ValueError(
                        f"family {mcfg.family!r} (window={mcfg.window}"
                        f"{'' if tag is None else f', model {tag!r}'}) does "
                        f"not support the paged KV cache")
        self.num_slots = ST.bucket_batch(num_slots)
        align = max(16, block_size) if block_size else 16
        self.max_seq = max_seq + (-max_seq) % align
        self.block_size = block_size
        if block_size:
            self.max_blocks = self.max_seq // block_size
            # default pool (PER LANE): every slot can hold a full row
            # privately, +1 for the reserved trash block — byte-parity
            # with contiguous rows; pass a smaller num_blocks for
            # memory-bound admission
            self.num_blocks = (num_blocks if num_blocks is not None
                               else self.num_slots * self.max_blocks + 1)
            if self.num_blocks < 2:
                raise ValueError(f"num_blocks must be >= 2, "
                                 f"got {self.num_blocks}")
        else:
            self.max_blocks = 0
            self.num_blocks = 0
        # chunked prefill: cap rounds up to the same power-of-two ladder,
        # so chunk shapes and pool shapes share one bounded compile set
        self.prefill_chunk = (ST.bucket_batch(prefill_chunk)
                              if prefill_chunk else None)
        self.policy = policy or bt.AdmissionPolicy(
            lambda b: 0.0, max_batch=self.num_slots, max_wait_s=0.0)
        # draft catch-up dispatch cap: per-tick gaps are <= 1 (a full
        # accept), but admission/resume rebuilds feed whole prompts
        self._draft_cap = (self.prefill_chunk or 16) if spec_k > 0 else 0
        # build the lanes: per-lane speculative resolution — a
        # multiplexed lane whose family cannot draft serves
        # non-speculatively ("where supported"), the single-model path
        # keeps its hard error above
        self.lanes: Dict[Optional[str], _Lane] = {}
        for order, (tag, (mcfg, mparams)) in enumerate(lane_cfgs.items()):
            lk = spec_k
            dcfg = dparams = None
            if spec_k > 0:
                if self.multi and not R.supports_speculation(mcfg):
                    lk = 0
                elif draft_layers is not None:
                    dcfg = R.draft_config(mcfg, draft_layers)
                    dparams = R.draft_params(mcfg, mparams, draft_layers)
                else:
                    dcfg, dparams = draft
            self.lanes[tag] = _Lane(self, tag, order, mcfg, mparams,
                                    lk, dcfg, dparams)
        # legacy aliases: the single-model engine's config/params (and
        # draft pair) remain reachable where old code expects them
        lane0 = next(iter(self.lanes.values()))
        self.cfg, self.params = lane0.cfg, lane0.params
        self.dcfg, self.dparams = lane0.dcfg, lane0.dparams

    def warmup(self) -> None:
        """Trace + compile every lane's slot step (and, when chunked
        prefill is on, every reachable chunk bucket) on throwaway caches
        so a wall-clock ``serve`` charges its first tick to serving, not
        to compilation."""
        with warnings.catch_warnings():
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            S = self.num_slots
            for ln in self.lanes.values():
                cache = ln._init_cache()
                if ln._prime_step is not None:
                    cache = ln._prime_step(
                        ln.params,
                        jnp.zeros((1, R.source_len(ln.cfg),
                                   ln.cfg.d_model), jnp.bfloat16),
                        cache, jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32))
                if ln.spec_k > 0:
                    # speculative serve never dispatches the 1-token
                    # fused step: warm what it DOES run — verify,
                    # propose, and the draft's catch-up chunk buckets
                    _, cache, _ = ln._verify(
                        jnp.zeros((S, ln.spec_k + 1), jnp.int32), cache,
                        jnp.zeros((S,), jnp.int32),
                        jnp.zeros((S,), jnp.int32),
                        jnp.zeros((S,), bool))
                    dcache = R.init_cache(ln.dcfg, S, self.max_seq)
                    _, dcache, _ = ln._propose_step(
                        ln.dparams, jnp.zeros((S, 1), jnp.int32), dcache,
                        jnp.zeros((S,), jnp.int32), jnp.zeros((S,), bool))
                    c = 1
                    while c <= self._draft_cap:
                        dcache = ln._draft_chunk_step(c)(
                            ln.dparams, jnp.zeros((c,), jnp.int32), dcache,
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32))
                        c *= 2
                else:
                    _, cache, _ = ln._fused(
                        jnp.zeros((S, 1), jnp.int32), cache,
                        jnp.zeros((S,), jnp.int32),
                        jnp.zeros((S,), bool))
                if self.prefill_chunk:
                    # every reachable bucket: remainder chunks bucket to
                    # the smaller powers of two, and a cold compile
                    # mid-serve is exactly what this warmup exists to
                    # keep off the clock
                    c = 1
                    while c <= self.prefill_chunk:
                        cache = ln._chunk_step(c)(
                            ln.params, jnp.zeros((c,), jnp.int32), cache,
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32))
                        c *= 2

    def serve(self, requests: Sequence[EngineRequest], *,
              clock: str = "virtual",
              tick_s: Union[float, Callable[[int], float]] = 1e-3,
              max_ticks: Optional[int] = None,
              drop_missed_deadlines: bool = False,
              preemption: bool = False,
              fault_plan: Optional[FaultPlan] = None,
              max_retries: int = 3) -> EngineReport:
        """Serve a whole request trace; return per-request outputs and
        achieved latency/throughput/occupancy metrics.

        ``clock="virtual"``: time advances ``tick_s`` per tick (or
        ``tick_s(active_count)`` when callable) — fully deterministic,
        used by tests and the offline benchmark.  ``clock="wall"``: time
        is the measured host clock — the live mode, where arrivals
        interleave with real step latency and a rolling-median watchdog
        flags stuck ticks (``EngineReport.stuck_ticks``).

        ``drop_missed_deadlines=True`` retires a slot the tick its
        deadline passes (possibly mid-prefill, before any token): its
        result is recorded with ``dropped=True``, whatever it generated,
        and — crucially — the ``first_token_s = -1.0`` sentinel, which
        the ttft aggregates below exclude.

        ``preemption=True`` lets admission-time pressure (no free slot,
        or a paged block claim the pool cannot cover) evict the active
        slot of strictly lower SLO class than the pending head — latest
        deadline first.  The victim's blocks are released, its host
        progress stashed, and it re-enters the pending queue; on
        re-admission its ``prompt + generated-so-far`` is teacher-forced
        through the chunked-prefill path, so the resumed output is
        bit-for-bit the never-preempted output (docs/serving.md).

        ``fault_plan`` injects a seeded :class:`FaultPlan`'s failures at
        their scheduled ticks; the recovery machinery (always on)
        retries failed dispatches, rebuilds slots that sample the
        non-finite sentinel or lose a torn block-table row, and retires
        a slot still faulting after ``max_retries`` recovery attempts
        with the typed ``failed`` status — one poisoned slot never takes
        down the cohort.

        On a multiplexed engine (``Engine(models={...})``) every
        request's ``model`` tag must name an admitted lane; the tick
        loop then interleaves one fused dispatch per lane with live
        slots, ``num_slots`` caps TOTAL active slots across lanes
        (dynamic leasing), and fault injection sees dense global slot
        ids (``lane.order * num_slots + sid``) so one seeded plan
        strikes across models deterministically.  All per-model state —
        cache, block pool, draft state, table mirror — stays
        lane-private (decode-contract rule 8).
        """
        if clock not in ("virtual", "wall"):
            raise ValueError(f"clock must be 'virtual' or 'wall': {clock!r}")
        for r in requests:
            mtag = getattr(r, "model", None)
            if mtag not in self.lanes:
                raise ValueError(
                    f"request {r.rid}: model {mtag!r} is not admitted on "
                    f"this engine (lanes: "
                    f"{[t for t in self.lanes]})")
            lane_r = self.lanes[mtag]
            if r.max_new_tokens <= 0:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be positive "
                    f"(got {r.max_new_tokens})")
            need = len(r.prompt) + r.max_new_tokens
            if need > self.max_seq:
                raise RequestTooLong(
                    f"request {r.rid} needs {need} cache positions > "
                    f"max_seq={self.max_seq}")
            if self.block_size:
                nb = -(-need // self.block_size)
                if nb > self.num_blocks - 1:
                    # would wait forever even against an empty pool
                    raise RequestTooLong(
                        f"request {r.rid} needs {nb} KV blocks > "
                        f"{self.num_blocks - 1} usable in the pool")
            if lane_r._prime_step is not None:
                _validate_source(lane_r.cfg, r)
        reqs = sorted(requests, key=lambda r: r.arrival_s)
        by_rid = {r.rid: r for r in reqs}
        S = self.num_slots
        lanes = list(self.lanes.values())      # index == lane.order
        for ln in lanes:
            ln.reset()
        sched = SlotScheduler(self.policy)
        results: List[RequestResult] = []
        occupancy: List[int] = []
        occ_by_lane: Dict[str, List[int]] = (
            {ln.tag: [] for ln in lanes} if self.multi else {})
        admissions_while_busy = 0
        dropped = 0
        ticks = 0
        gen_tokens = 0
        # a row-tick that commits >= 1 token is one "emitting dispatch":
        # accepted_per_dispatch = gen_tokens / emit_dispatches is exactly
        # 1.0 without speculation and the mean accepted+bonus run length
        # with it — the honest denominator for speculative throughput
        emit_dispatches = 0
        # overload robustness state: stashed progress of preempted
        # requests (rid -> _Stash) and the fault/recovery counters
        stash: Dict[int, _Stash] = {}
        preempted = failed = unfinished = 0
        dispatch_retries = nonfinite = torn_repaired = 0
        wd = StepWatchdog() if clock == "wall" else None
        # paged-mode state lives per lane (lane.bpool / lane.tables_np);
        # the aggregate counters below span lanes
        paged = self.block_size is not None
        shared_hits = 0
        skipped_tokens = 0
        blocks_demanded = 0
        peak_used = 0
        util_sum = 0.0

        def total_active() -> int:
            return sum(ln.pool.active_count for ln in lanes)

        def _register_blocks(ln, st) -> None:
            # publish each prompt block for prefix sharing the moment the
            # slot's frontier passes its end (its KV writes are already
            # issued in dispatch order, so any later gather sees them)
            while (st.registered < len(st.prompt_keys)
                   and st.pos >= (st.registered + 1) * self.block_size):
                ln.bpool.register(st.prompt_keys[st.registered],
                                  st.block_table[st.registered])
                st.registered += 1

        def _release_blocks(ln, st) -> None:
            for bid in st.block_table:
                ln.bpool.release(bid)
            st.block_table, st.prompt_keys, st.registered = None, (), 0
            ln.tables_np[st.sid, :] = 0       # retired row scatters to trash
            ln.tables_dirty = True

        def _eff_req(req: EngineRequest) -> EngineRequest:
            """The request as (re-)admission sees it: a preempted request
            resumes with its stashed tokens appended to the prompt
            (teacher-forced through prefill — the exact-resume mechanism)
            and its token budget reduced by the same count, so its total
            cache claim is invariant under preemption."""
            s = stash.get(req.rid)
            if s is None or not s.generated:
                return req
            return dataclasses.replace(
                req, prompt=req.prompt + tuple(s.generated),
                max_new_tokens=req.max_new_tokens - len(s.generated))

        def _preempt(ln, st) -> None:
            """Evict a live slot with exact-resume semantics: release its
            blocks, stash host progress, requeue the original request.
            No device state survives — resume rebuilds it all."""
            nonlocal preempted
            preempted += 1
            rid = st.rid                  # pool.free() scrubs it to -1
            stash[rid] = _Stash(
                generated=list(st.generated or []),
                first_token_s=st.first_token_s, admit_s=st.admit_s,
                preemptions=st.preemptions + 1, retries=st.retries)
            if paged and st.block_table is not None:
                _release_blocks(ln, st)
            ln.pool.free(st.sid)
            ln.index[st.sid] = 0
            ln.tokens[st.sid, 0] = 0
            sched.push(by_rid[rid])

        def _fail(ln, st) -> None:
            """Retire a slot fault recovery gave up on (typed status)."""
            nonlocal failed
            failed += 1
            results.append(RequestResult(
                rid=st.rid, tokens=list(st.generated or []),
                arrival_s=st.arrival_s, admit_s=st.admit_s,
                first_token_s=st.first_token_s, finish_s=now,
                slot=st.sid, status="failed", priority=st.priority,
                preemptions=st.preemptions, deadline_s=st.deadline_s,
                model=ln.tag))
            if paged and st.block_table is not None:
                _release_blocks(ln, st)
            ln.pool.free(st.sid)
            ln.index[st.sid] = 0
            ln.tokens[st.sid, 0] = 0

        i, now = 0, 0.0
        t0 = time.perf_counter()
        limit = max_ticks if max_ticks is not None else \
            (sum(len(r.prompt) + r.max_new_tokens for r in reqs) + 16) * 4

        with warnings.catch_warnings():
            # CPU backends warn that donated buffers were not usable
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            while i < len(reqs) or sched.pending or total_active():
                # 1) ingest everything that has arrived by `now`
                while i < len(reqs) and reqs[i].arrival_s <= now:
                    sched.push(reqs[i])
                    i += 1
                next_arrival = reqs[i].arrival_s if i < len(reqs) else None
                # 2) admit into free slot leases — mid-flight, no drain
                #    barrier; `num_slots` caps the TOTAL across lanes
                generating = any(s.active and not s.in_prefill
                                 for ln in lanes for s in ln.pool.slots)
                if preemption and sched.pending:
                    # resource pressure + a strictly-higher-class head:
                    # evict the lowest-class generating slot (latest
                    # deadline first) until the head fits or no victim of
                    # lower class remains — equal class never preempts,
                    # so batch can't thrash batch.  Slot pressure frees a
                    # LEASE, so victims come from any lane; pure block
                    # pressure only helps if the victim is in the head's
                    # own lane (block pools are lane-private, rule 8).
                    head = sched.pending[0]
                    lane_h = self.lanes[getattr(head, "model", None)]
                    hrank = bt.priority_rank(
                        getattr(head, "priority", bt.PRIORITY_CLASSES[0]))
                    for _ in range(S * len(lanes)):
                        slot_pressed = total_active() >= S
                        block_pressed = (
                            paged and lane_h._block_cost(_eff_req(head))
                            > lane_h.bpool.free_blocks)
                        if not (slot_pressed or block_pressed):
                            break
                        vlanes = lanes if slot_pressed else [lane_h]
                        victims = [(ln, s) for ln in vlanes
                                   for s in ln.pool.active_slots()
                                   if bt.priority_rank(s.priority) > hrank]
                        if not victims:
                            break
                        ln_v, st_v = max(victims, key=lambda t: (
                            bt.priority_rank(t[1].priority), t[1].deadline_s,
                            t[0].order, t[1].sid))
                        _preempt(ln_v, st_v)
                quotas_on = bool(self.policy.class_quotas)
                abc = None
                if quotas_on or self.multi:
                    # quota denominators: on a multiplexed engine each
                    # active slot charges its (model, class) tuple AND the
                    # bare model and class keys, so quotas configured at
                    # any granularity meter correctly
                    abc = {}
                    for ln in lanes:
                        for s in ln.pool.active_slots():
                            if self.multi:
                                for k in ((ln.tag, s.priority), ln.tag,
                                          s.priority):
                                    abc[k] = abc.get(k, 0) + 1
                            else:
                                abc[s.priority] = abc.get(s.priority, 0) + 1
                if paged:
                    budget = ({ln.tag: ln.bpool.free_blocks for ln in lanes}
                              if self.multi else lanes[0].bpool.free_blocks)
                else:
                    budget = None
                cohort = sched.admit(
                    now, S - total_active(), next_arrival,
                    cost_fn=((lambda r: self.lanes[getattr(r, "model", None)]
                              ._block_cost(_eff_req(r)))
                             if paged else None),
                    budget=budget,
                    active_by_class=abc,
                    key_fn=((lambda r: (getattr(r, "model", None),
                                        getattr(r, "priority",
                                                bt.PRIORITY_CLASSES[0])))
                            if self.multi else None))
                admitted = 0
                for req in cohort:
                    ln = self.lanes[getattr(req, "model", None)]
                    s_res = stash.get(req.rid)
                    if drop_missed_deadlines and now > req.deadline_s:
                        # expired while queued: retire WITHOUT taking a
                        # slot — no prime or prefill dispatch is wasted
                        # on a request that is already dead (a preempted
                        # request keeps what it had generated)
                        results.append(RequestResult(
                            rid=req.rid,
                            tokens=list(s_res.generated) if s_res else [],
                            arrival_s=req.arrival_s,
                            admit_s=s_res.admit_s if s_res else now,
                            first_token_s=(s_res.first_token_s if s_res
                                           else -1.0),
                            finish_s=now, slot=-1, dropped=True,
                            status="dropped", priority=req.priority,
                            preemptions=s_res.preemptions if s_res else 0,
                            deadline_s=req.deadline_s, model=ln.tag))
                        stash.pop(req.rid, None)
                        dropped += 1
                        continue
                    admitted += 1
                    eff = _eff_req(req)
                    st = ln.pool.alloc(req.rid, eff.prompt,
                                       eff.max_new_tokens,
                                       now=now, arrival_s=req.arrival_s,
                                       deadline_s=req.deadline_s,
                                       priority=req.priority)
                    if s_res is not None:
                        # exact resume: the stashed tokens ride the prompt
                        # (teacher-forced), the generated list starts from
                        # them, and ttft/admit bookkeeping survives the
                        # eviction — alloc validated the INVARIANT claim
                        # eff.prompt + eff.max_new == original total
                        st.generated = list(s_res.generated)
                        st.max_new = req.max_new_tokens
                        st.first_token_s = s_res.first_token_s
                        st.admit_s = s_res.admit_s
                        st.preemptions = s_res.preemptions
                        st.retries = s_res.retries
                        del stash[req.rid]
                    ln.index[st.sid] = 0
                    if paged:
                        # build the slot's block table: ref every shared
                        # prefix block (their prefill chunks are skipped
                        # entirely), alloc the rest privately — the
                        # admission decision priced exactly this claim.
                        # Keys are model-fingerprinted (lane._prefix_keys)
                        # and looked up in the lane's OWN pool, so a hit
                        # can never cross models.
                        keys = ln._prefix_keys(eff)
                        hits = ln._usable_hits(eff, keys)
                        need = -(-(len(eff.prompt) + eff.max_new_tokens)
                                 // self.block_size)
                        table = []
                        for j in range(hits):
                            bid = ln.bpool.lookup(keys[j])
                            ln.bpool.ref(bid)
                            table.append(bid)
                        for _ in range(need - hits):
                            table.append(ln.bpool.alloc())
                        st.block_table = table
                        st.prompt_keys = keys
                        st.registered = hits
                        st.pos = hits * self.block_size
                        ln.index[st.sid] = st.pos
                        ln.tables_np[st.sid, :] = 0
                        ln.tables_np[st.sid, :len(table)] = table
                        ln.tables_dirty = True
                        shared_hits += hits
                        skipped_tokens += hits * self.block_size
                        blocks_demanded += need
                    if ln._prime_step is not None:
                        # prime dispatch: write this slot's cross-K/V row
                        # (and its xlen frontier) once, concurrently with
                        # other slots' decoding — like a prefill chunk,
                        # its cost lands on this tick's clock (resume
                        # re-primes: reconstructed, never trusted)
                        src, n_valid = _padded_source(ln.cfg, req)
                        ln.cache = ln._prime_step(
                            ln.params, src, ln.cache,
                            jnp.asarray(st.sid, jnp.int32), n_valid)
                    left = len(st.prompt) - 1 - st.pos
                    if self.prefill_chunk and left > 0:
                        # remaining prompt (all but the last token, minus
                        # any shared-prefix positions already resident)
                        # goes through the chunked prefill step; the last
                        # token rides the fused step (its sample = first
                        # output token)
                        st.chunk_left = left
                    else:
                        ln.tokens[st.sid, 0] = st.next_input()
                if generating:
                    admissions_while_busy += admitted
                if paged:
                    # push each dirty host table mirror before any
                    # dispatch this tick gathers or scatters through it
                    for ln in lanes:
                        if ln.tables_dirty:
                            ln.cache = dict(
                                ln.cache,
                                block_tables=jnp.asarray(ln.tables_np))
                            ln.tables_dirty = False
                # 3) idle: nothing active -> jump to the next event
                if total_active() == 0:
                    if next_arrival is None and not sched.pending:
                        break
                    if next_arrival is None and not cohort:
                        # this round consumed nothing from a non-empty
                        # queue, the pool is idle, and nothing is left to
                        # arrive: no future round can differ — surface
                        # the policy bug instead of spinning (the
                        # virtual-time twin of the run_virtual guard)
                        raise RuntimeError(
                            "admission declined a non-empty pending queue "
                            f"({len(sched.pending)} requests) with an idle "
                            "pool and no future arrival; check the policy "
                            "/ class_quotas configuration")
                    target = next_arrival if next_arrival is not None else now
                    if clock == "wall":
                        gap = target - (time.perf_counter() - t0)
                        if gap > 0:
                            time.sleep(min(gap, 0.05))
                        now = time.perf_counter() - t0
                    else:
                        now = max(now, target)
                    continue
                # 4) chunked prefill: each mid-prefill slot writes one
                #    bucketed chunk of teacher-forced prompt state in a
                #    single dispatch (admission-to-first-token shrinks
                #    from prompt_len ticks to ceil(prompt_len/chunk))
                for ln in lanes:
                    for st in ln.pool.active_slots():
                        if st.chunk_left <= 0:
                            continue
                        n = min(st.chunk_left, self.prefill_chunk)
                        c = ST.bucket_batch(n)
                        buf = np.zeros((c,), np.int32)
                        buf[:n] = st.prompt[st.pos:st.pos + n]
                        ln.cache = ln._chunk_step(c)(
                            ln.params, jnp.asarray(buf), ln.cache,
                            jnp.asarray(st.sid, jnp.int32),
                            jnp.asarray(st.pos, jnp.int32),
                            jnp.asarray(n, jnp.int32))
                        st.pos += n
                        st.chunk_left -= n
                        ln.index[st.sid] = st.pos
                        if paged:
                            _register_blocks(ln, st)
                        if st.chunk_left == 0:
                            ln.tokens[st.sid, 0] = st.prompt[st.pos]
                # 4.5) speculative draft: catch each generating slot's
                #      draft cache up to its committed frontier (teacher-
                #      forced — this is also what rebuilds the draft after
                #      admission, preemption/resume, or slot reuse), then
                #      propose k greedy tokens per slot in ONE fused
                #      dispatch per speculating lane.  Draft dispatches
                #      see no fault injection: a wrong proposal can only
                #      be rejected.
                for ln in lanes:
                    if not ln.spec:
                        continue
                    ln.krow = np.zeros((S,), np.int32)
                    for st in ln.pool.active_slots():
                        if st.chunk_left > 0 or st.pos < len(st.prompt) - 1:
                            continue
                        k_row = min(ln.spec_k,
                                    st.max_new - len(st.generated) - 1,
                                    self.max_seq - 1 - st.pos)
                        if k_row <= 0:
                            continue
                        ln.krow[st.sid] = k_row
                        P = len(st.prompt)
                        while st.draft_pos < st.pos:
                            n = min(st.pos - st.draft_pos, self._draft_cap)
                            c = ST.bucket_batch(n)
                            buf = np.zeros((c,), np.int32)
                            for t in range(n):
                                p = st.draft_pos + t
                                buf[t] = (st.prompt[p] if p < P
                                          else st.generated[p - P])
                            ln.draft_cache = ln._draft_chunk_step(c)(
                                ln.dparams, jnp.asarray(buf),
                                ln.draft_cache,
                                jnp.asarray(st.sid, jnp.int32),
                                jnp.asarray(st.draft_pos, jnp.int32),
                                jnp.asarray(n, jnp.int32))
                            st.draft_pos += n
                    d_active = ln.krow > 0
                    if d_active.any():
                        d_index = np.array(
                            [s.draft_pos for s in ln.pool.slots], np.int32)
                        props, ln.draft_cache, _ = ln._propose_step(
                            ln.dparams, jnp.asarray(ln.tokens),
                            ln.draft_cache,
                            jnp.asarray(d_index), jnp.asarray(d_active))
                        ln.props = np.asarray(props)
                    else:
                        ln.props = np.zeros((S, ln.spec_k), np.int32)
                # 5) one fused slot-masked step PER LANE with live slots:
                #    every ready slot (not mid-chunk), one token — or,
                #    speculating, one wide verify dispatch scoring 1..k+1
                #    tokens per ready slot (same single compiled shape per
                #    lane whatever the mix).  Fault injection addresses
                #    slots by dense GLOBAL id (lane.order * S + sid) so a
                #    single-lane engine sees byte-identical sid streams.
                all_ready: List[int] = []      # global ids, lane-major
                for ln in lanes:
                    ln.active_mask = np.array(
                        [s.active and s.chunk_left == 0
                         for s in ln.pool.slots], bool)
                    ln.ready = [int(s) for s in np.where(ln.active_mask)[0]]
                    ln.torn = []
                    ln.nxt = None
                    all_ready.extend(ln.order * S + sid for sid in ln.ready)
                if fault_plan is not None and paged and all_ready:
                    # fault: tear the victim's DEVICE table row (zero ->
                    # all-trash) just before dispatch; the host mirror
                    # stays clean, which is exactly how the post-step
                    # audit knows what to rebuild
                    for g in fault_plan.torn_rows(ticks, all_ready):
                        lanes[g // S].torn.append(g % S)
                    for ln in lanes:
                        if ln.torn:
                            torn = ln.tables_np.copy()
                            for sid in ln.torn:
                                torn[sid, :] = 0
                            ln.cache = dict(ln.cache,
                                            block_tables=jnp.asarray(torn))
                            ln.tables_dirty = True  # clean mirror repushed
                if all_ready:
                    # resolve dispatch faults FIRST, over the union of
                    # ready global ids (the injected fault strikes the
                    # tick's dispatch sequence, whichever lane the culprit
                    # sits in), then run each lane's step exactly once
                    attempt = 0
                    while all_ready:
                        culprit = (fault_plan.dispatch_fault(
                            ticks, attempt, all_ready)
                            if fault_plan is not None else None)
                        if culprit is None:
                            break
                        # dispatch failed: charge the culprit's retry
                        # budget; past max_retries the request is retired
                        # as `failed` and the retry goes on without it —
                        # one poisoned slot never takes down the cohort
                        dispatch_retries += 1
                        attempt += 1
                        ln = lanes[culprit // S]
                        sid = culprit % S
                        st = ln.pool.slots[sid]
                        st.retries += 1
                        if st.retries > max_retries:
                            _fail(ln, st)
                            ln.active_mask[sid] = False
                            ln.ready.remove(sid)
                            all_ready.remove(culprit)
                for ln in lanes:
                    if not ln.ready:
                        continue
                    if ln.spec:
                        # per-row verify payload: the committed next input
                        # in column 0, the row's usable proposals after it
                        ln.tok_mat = np.zeros((S, ln.spec_k + 1), np.int32)
                        ln.tok_mat[:, 0] = ln.tokens[:, 0]
                        for sid in ln.ready:
                            kr = int(ln.krow[sid])
                            if kr > 0:
                                ln.tok_mat[sid, 1:1 + kr] = \
                                    ln.props[sid, :kr]
                        ln.n_tok = np.where(ln.active_mask, 1 + ln.krow,
                                            0).astype(np.int32)
                        nxt, ln.cache, new_index = ln._verify(
                            ln.tok_mat, ln.cache, ln.index, ln.n_tok,
                            ln.active_mask)
                    else:
                        nxt, ln.cache, new_index = ln._fused(
                            ln.tokens, ln.cache, ln.index, ln.active_mask)
                    ln.nxt = np.asarray(nxt)
                    ln.index = np.array(new_index)   # writable host copy
                if not all_ready and clock == "wall":
                    # charge chunk/prime time here
                    jax.block_until_ready([ln.cache for ln in lanes])
                if fault_plan is not None and all_ready:
                    # fault: poison chosen slots' logits — modelled at the
                    # guard's observable surface, the -1 sentinel the
                    # in-graph finite check emits for NaN/Inf rows
                    for g in fault_plan.nonfinite_slots(ticks, all_ready):
                        ln = lanes[g // S]
                        ln.nxt = np.array(ln.nxt)    # writable copy
                        ln.nxt[g % S] = -1
                ticks += 1
                tact = total_active()
                occupancy.append(tact)
                for t in occ_by_lane:
                    occ_by_lane[t].append(self.lanes[t].pool.active_count)
                if paged:
                    used = sum(ln.bpool.used_blocks for ln in lanes)
                    peak_used = max(peak_used, used)
                    util_sum += used / max(
                        1, (self.num_blocks - 1) * len(lanes))
                if clock == "wall":
                    # np.asarray(nxt) above already blocked on the step
                    prev = now
                    now = time.perf_counter() - t0
                    # stuck-tick watchdog: with static shapes, per-tick
                    # wall time is tight — a straggler means a sick
                    # host, not workload variance
                    msg = wd.record(now - prev)
                    if msg:
                        warnings.warn(f"engine tick {ticks}: {msg}",
                                      RuntimeWarning)
                else:
                    dt = tick_s(tact) if callable(tick_s) else tick_s
                    now += dt
                # 6) host bookkeeping, lane by lane: teacher-force
                #    prefill, collect samples, retire finished slots for
                #    immediate lease reuse (by any lane)
                for ln in lanes:
                  for sid in ln.torn:
                    # the torn row sent this tick's K/V write to trash
                    # and sampled through garbage gathers: the slot's
                    # device state can no longer be trusted, so the
                    # audit repairs the table (clean mirror repush) and
                    # rebuilds the tenant from scratch via preemption —
                    # its output stays bit-for-bit (exact resume)
                    st = ln.pool.slots[sid]
                    if not st.active:
                        continue          # already retired by _fail
                    torn_repaired += 1
                    _preempt(ln, st)
                  for st in ln.pool.active_slots():
                    if st.sid in ln.torn:
                        continue
                    if drop_missed_deadlines and now > st.deadline_s:
                        # deadline miss — possibly mid-prefill, before
                        # any token: record with the first_token_s
                        # sentinel intact (ttft aggregates exclude it)
                        results.append(RequestResult(
                            rid=st.rid, tokens=list(st.generated),
                            arrival_s=st.arrival_s, admit_s=st.admit_s,
                            first_token_s=st.first_token_s, finish_s=now,
                            slot=st.sid, dropped=True, status="dropped",
                            priority=st.priority,
                            preemptions=st.preemptions,
                            deadline_s=st.deadline_s, model=ln.tag))
                        dropped += 1
                        if paged:
                            _release_blocks(ln, st)
                        ln.pool.free(st.sid)
                        continue
                    if st.chunk_left > 0:          # mid-chunk: no sample
                        continue
                    if not ln.spec:
                        st.pos += 1
                        if paged:
                            _register_blocks(ln, st)
                        if st.pos < len(st.prompt):    # still prefilling
                            ln.tokens[st.sid, 0] = st.prompt[st.pos]
                            continue
                        tok = int(ln.nxt[st.sid])
                        if tok < 0:
                            # the in-graph finite guard's sentinel: this
                            # slot's logits went NaN/Inf.  The sample is
                            # garbage and the cache row suspect — rebuild
                            # deterministically via preemption (a transient
                            # fault recomputes clean, bit-for-bit); a slot
                            # that keeps faulting exhausts its retry budget
                            # and is retired as `failed`
                            nonfinite += 1
                            st.retries += 1
                            if st.retries > max_retries:
                                _fail(ln, st)
                            else:
                                _preempt(ln, st)
                            continue
                        st.generated.append(tok)
                        gen_tokens += 1
                        emit_dispatches += 1
                        if st.first_token_s < 0:
                            st.first_token_s = now
                        if st.done():
                            results.append(RequestResult(
                                rid=st.rid, tokens=list(st.generated),
                                arrival_s=st.arrival_s, admit_s=st.admit_s,
                                first_token_s=st.first_token_s,
                                finish_s=now,
                                slot=st.sid, priority=st.priority,
                                preemptions=st.preemptions,
                                deadline_s=st.deadline_s, model=ln.tag))
                            if paged:
                                _release_blocks(ln, st)
                            ln.pool.free(st.sid)
                        else:
                            ln.tokens[st.sid, 0] = tok
                        continue
                    # speculative commit: walk the verified row, keeping
                    # the accepted prefix + the bonus sample, then REWIND
                    # the device index to the committed frontier — the
                    # rejected tail's KV writes die by overwrite-before-
                    # read (decode-contract rule 7)
                    nt = int(ln.n_tok[st.sid])
                    row = ln.nxt[st.sid]
                    if np.any(row[:nt] < 0):
                        # any sentinel in the fed range poisons the whole
                        # round: in-flight proposals are uncommitted state,
                        # so fault recovery rebuilds from the last COMMITTED
                        # token exactly as in the non-speculative engine
                        nonfinite += 1
                        st.retries += 1
                        if st.retries > max_retries:
                            _fail(ln, st)
                        else:
                            _preempt(ln, st)
                        continue
                    pos0 = st.pos
                    committed = 0
                    for j in range(nt):
                        st.pos += 1
                        if paged:
                            _register_blocks(ln, st)
                        if st.pos < len(st.prompt):    # still prefilling
                            ln.tokens[st.sid, 0] = st.prompt[st.pos]
                            break
                        tok = int(row[j])
                        st.generated.append(tok)
                        gen_tokens += 1
                        committed += 1
                        if st.first_token_s < 0:
                            st.first_token_s = now
                        if st.done() or (j + 1 < nt
                                         and tok != int(ln.tok_mat[st.sid,
                                                                   j + 1])):
                            break
                    ln.index[st.sid] = st.pos  # the rewind past rejections
                    if committed:
                        emit_dispatches += 1
                        if ln.krow[st.sid] > 0:
                            # the draft consumed [f, d_1..d_{k-1}]; the
                            # committed-valid prefix of that is 1 + the
                            # accepted count (capped at k-1): gap 0 after
                            # a partial accept, 1 after a full accept
                            st.draft_pos = pos0 + 1 + min(
                                committed - 1, ln.spec_k - 1)
                    if st.done():
                        results.append(RequestResult(
                            rid=st.rid, tokens=list(st.generated),
                            arrival_s=st.arrival_s, admit_s=st.admit_s,
                            first_token_s=st.first_token_s, finish_s=now,
                            slot=st.sid, priority=st.priority,
                            preemptions=st.preemptions,
                            deadline_s=st.deadline_s, model=ln.tag))
                        if paged:
                            _release_blocks(ln, st)
                        ln.pool.free(st.sid)
                    elif committed:
                        ln.tokens[st.sid, 0] = st.generated[-1]
                if ticks > limit:
                    # the cap exists to bound a stuck run; hitting it is
                    # an overload outcome, not a crash — retire everything
                    # still in flight (and everything that never got in)
                    # with the typed `unfinished` status and report it
                    warnings.warn(
                        f"engine hit the {limit}-tick cap with "
                        f"{total_active()} active, "
                        f"{len(sched.pending)} pending and "
                        f"{len(reqs) - i} unarrived requests; retiring "
                        "them as 'unfinished'", RuntimeWarning)
                    for ln in lanes:
                        for st in ln.pool.active_slots():
                            unfinished += 1
                            results.append(RequestResult(
                                rid=st.rid, tokens=list(st.generated or []),
                                arrival_s=st.arrival_s, admit_s=st.admit_s,
                                first_token_s=st.first_token_s,
                                finish_s=now,
                                slot=st.sid, status="unfinished",
                                priority=st.priority,
                                preemptions=st.preemptions,
                                deadline_s=st.deadline_s, model=ln.tag))
                            if paged:
                                _release_blocks(ln, st)
                            ln.pool.free(st.sid)
                    for req in list(sched.pending) + reqs[i:]:
                        s_res = stash.pop(req.rid, None)
                        unfinished += 1
                        results.append(RequestResult(
                            rid=req.rid,
                            tokens=list(s_res.generated) if s_res else [],
                            arrival_s=req.arrival_s,
                            admit_s=s_res.admit_s if s_res else -1.0,
                            first_token_s=(s_res.first_token_s if s_res
                                           else -1.0),
                            finish_s=now, slot=-1, status="unfinished",
                            priority=req.priority,
                            preemptions=s_res.preemptions if s_res else 0,
                            deadline_s=req.deadline_s,
                            model=getattr(req, "model", None)))
                    sched.pending.clear()
                    i = len(reqs)
                    break

        wall = time.perf_counter() - t0
        results.sort(key=lambda r: r.rid)
        lat = [r.latency_s for r in results if r.status == "ok"]
        # a request retired before emitting a token still carries the
        # first_token_s = -1.0 sentinel: it must never leak a negative
        # ttft into the aggregates
        ttft = [r.ttft_s for r in results if r.emitted]
        dur = max(now, 1e-12)
        kv_bytes = int(sum(x.size * x.dtype.itemsize
                           for ln in lanes
                           for x in jax.tree_util.tree_leaves(ln.cache)))
        # per-SLO-class tails + goodput: only a completed request that
        # met its deadline counts toward the honest metric at scale
        by_class: Dict[str, List[RequestResult]] = {}
        for r in results:
            by_class.setdefault(r.priority, []).append(r)
        cls_lat = {c: bt.p99([r.latency_s for r in rs if r.status == "ok"])
                   for c, rs in sorted(by_class.items())}
        cls_ttft = {c: [r.ttft_s for r in rs if r.emitted]
                    for c, rs in sorted(by_class.items())}
        good = [r for r in results
                if r.status == "ok" and r.finish_s <= r.deadline_s]
        good_tokens = sum(len(r.tokens) for r in good)
        lat_tok = [r.latency_s / len(r.tokens) for r in results
                   if r.status == "ok" and r.tokens]
        # per-model aggregates (multiplexed engines only; empty dicts on a
        # single-model engine keep its report byte-identical)
        mdl_lat: Dict[str, float] = {}
        mdl_ttft_mean: Dict[str, float] = {}
        mdl_ttft_p99: Dict[str, float] = {}
        mdl_goodput: Dict[str, float] = {}
        if self.multi:
            by_model: Dict[str, List[RequestResult]] = \
                {ln.tag: [] for ln in lanes}
            for r in results:
                by_model[r.model].append(r)
            for m, rs in by_model.items():
                mdl_lat[m] = bt.p99(
                    [r.latency_s for r in rs if r.status == "ok"])
                ts = [r.ttft_s for r in rs if r.emitted]
                mdl_ttft_mean[m] = float(np.mean(ts)) if ts else 0.0
                mdl_ttft_p99[m] = bt.p99(ts)
                mdl_goodput[m] = sum(
                    len(r.tokens) for r in rs
                    if r.status == "ok" and r.finish_s <= r.deadline_s
                ) / dur
        return EngineReport(
            results=results, ticks=ticks, generated_tokens=gen_tokens,
            duration_s=now, wall_s=wall,
            p99_latency_s=bt.p99(lat),
            tokens_per_s=gen_tokens / dur,
            occupancy=occupancy,
            mean_occupancy=(sum(occupancy) / (len(occupancy) * S)
                            if occupancy else 0.0),
            admissions_while_busy=admissions_while_busy,
            num_slots=S,
            mean_ttft_s=float(np.mean(ttft)) if ttft else 0.0,
            p99_ttft_s=bt.p99(ttft),
            prefill_chunk=self.prefill_chunk,
            dropped=dropped,
            block_size=self.block_size,
            num_blocks=self.num_blocks,
            kv_hbm_bytes=kv_bytes,
            peak_blocks_used=peak_used,
            mean_block_util=(util_sum / ticks if paged and ticks else 0.0),
            shared_block_hits=shared_hits,
            shared_hit_rate=(shared_hits / blocks_demanded
                             if blocks_demanded else 0.0),
            prefill_tokens_skipped=skipped_tokens,
            effective_concurrency=(sum(occupancy) / len(occupancy)
                                   if occupancy else 0.0),
            preempted=preempted,
            failed=failed,
            unfinished=unfinished,
            dispatch_retries=dispatch_retries,
            nonfinite_samples=nonfinite,
            torn_rows_repaired=torn_repaired,
            stuck_ticks=wd.slow_steps if wd is not None else 0,
            leaked_blocks=(sum((self.num_blocks - 1) - ln.bpool.free_blocks
                               for ln in lanes) if paged else 0),
            class_p99_latency_s=cls_lat,
            class_mean_ttft_s={c: (float(np.mean(ts)) if ts else 0.0)
                               for c, ts in cls_ttft.items()},
            class_p99_ttft_s={c: bt.p99(ts) for c, ts in cls_ttft.items()},
            goodput_tokens_per_s=good_tokens / dur,
            slo_attainment=(len(good) / len(results) if results else 0.0),
            spec_k=self.spec_k,
            accepted_per_dispatch=(gen_tokens / emit_dispatches
                                   if emit_dispatches else 0.0),
            latency_per_token_s=(float(np.mean(lat_tok))
                                 if lat_tok else 0.0),
            model_p99_latency_s=mdl_lat,
            model_mean_ttft_s=mdl_ttft_mean,
            model_p99_ttft_s=mdl_ttft_p99,
            model_goodput_tokens_per_s=mdl_goodput,
            model_mean_occupancy={
                t: (sum(v) / (len(v) * S) if v else 0.0)
                for t, v in occ_by_lane.items()},
            model_occupancy={t: list(v) for t, v in occ_by_lane.items()})


# ---------------------------------------------------------------------------
# sequential reference + trace synthesis (shared by tests / serve / bench)
# ---------------------------------------------------------------------------

def _validate_source(cfg: ArchConfig, req: EngineRequest) -> np.ndarray:
    """Host-side shape/length checks only (no device array is built —
    ``serve`` validates the whole trace up front before admitting
    anything, and builds the padded array once, at admission)."""
    smax = R.source_len(cfg)
    if req.source is None:
        raise ValueError(
            f"request {req.rid}: {cfg.family!r} serves against per-request "
            f"source embeddings; EngineRequest.source must be "
            f"(src_len <= {smax}, {cfg.d_model})")
    src = np.asarray(req.source, np.float32)
    if src.ndim != 2 or src.shape[1] != cfg.d_model:
        raise ValueError(
            f"request {req.rid}: source must be (src_len, {cfg.d_model}), "
            f"got {src.shape}")
    n = src.shape[0]
    if not 0 < n <= smax:
        raise ValueError(
            f"request {req.rid}: source length {n} outside (0, {smax}]")
    return src


def _padded_source(cfg: ArchConfig, req: EngineRequest):
    """One request's source embeddings padded to the static prime shape:
    (1, source_len(cfg), d_model) bf16 plus the () int32 count of real
    positions.  Shared by the engine's prime dispatch and the sequential
    reference, so both prime with byte-identical inputs — the pad is
    masked behind the row's xlen frontier at decode time."""
    src = _validate_source(cfg, req)
    n = src.shape[0]
    buf = np.zeros((1, R.source_len(cfg), cfg.d_model), np.float32)
    buf[0, :n] = src
    return (jnp.asarray(buf, jnp.bfloat16),
            jnp.asarray(n, jnp.int32))


def reference_outputs(cfg: ArchConfig, params,
                      requests: Sequence[EngineRequest], *,
                      mode: QuantMode = FP, max_seq: int = 64,
                      temperature: float = 0.0, rng=None
                      ) -> Dict[int, List[int]]:
    """The sequential per-token reference loop: each request alone at
    batch=1, prompt teacher-forced a token at a time, then greedy
    generation — the bit-for-bit baseline the engine must reproduce.

    With ``temperature > 0`` sampling draws with the
    ``fold_in(rng, position)`` key schedule — the same schedule
    :func:`repro.runtime.steps.make_decode_loop` and the slot engine use
    (per-row there), so sampled outputs stay engine-comparable."""
    if temperature > 0.0 and rng is None:
        raise ValueError("temperature sampling needs an rng key")
    decode = jax.jit(ST.make_decode_step(cfg, mode=mode))
    # encdec/vlm: the same prime computation the engine dispatches, at a
    # pool of one slot (no donation: the reference is not a hot path)
    prime = (jax.jit(ST.make_prime_step(cfg, mode=mode))
             if R.needs_prime(cfg) else None)
    out: Dict[int, List[int]] = {}
    for r in sorted(requests, key=lambda x: x.rid):
        cache = R.init_cache(cfg, 1, max_seq)
        if prime is not None:
            src, n_valid = _padded_source(cfg, r)
            cache = prime(params, src, cache,
                          jnp.zeros((), jnp.int32), n_valid)
        tok = None
        gen: List[int] = []
        feed = list(r.prompt)
        pos = 0
        while len(gen) < r.max_new_tokens:
            cur = feed[pos] if pos < len(feed) else tok
            # prime families decode with a (1,)-vector index: the per-row
            # path is where the xlen frontier masks the padded source, and
            # the engine's slot rows take exactly that path
            idx = (jnp.asarray([pos], jnp.int32) if prime is not None
                   else jnp.asarray(pos, jnp.int32))
            logits, cache = decode(
                params,
                {"tokens": jnp.asarray([[cur]], jnp.int32),
                 "cache_index": idx}, cache)
            pos += 1
            if pos >= len(feed):
                if temperature > 0.0:
                    key = jax.random.fold_in(
                        rng, jnp.asarray(pos - 1, jnp.int32))
                    tok = int(ST.temperature_sample(logits, key,
                                                    temperature)[0])
                else:
                    tok = int(ST.greedy_sample(logits)[0])
                gen.append(tok)
        out[r.rid] = gen
    return out


def synthetic_requests(n: int, *, rate_per_s: float, vocab: int,
                       prompt_len: int = 4, max_new_tokens: int = 8,
                       deadline_s: float = float("inf"),
                       seed: int = 0,
                       shared_prefix_len: int = 0,
                       source_shape: Optional[Tuple[int, int]] = None,
                       priority: Union[str, Callable[[int], str]]
                       = "interactive",
                       arrival_process: Optional[
                           Callable[[int, float, int], Sequence[float]]]
                       = None,
                       model: Union[None, str, Callable[[int], str]]
                       = None) -> List[EngineRequest]:
    """Deterministic pseudo-Poisson request trace with synthetic prompts
    (derived from the rid, so any two runs see identical streams).

    ``shared_prefix_len=k`` makes the first ``k`` prompt tokens identical
    across ALL requests (a seed-derived "system prompt") with rid-seeded
    suffixes after it — the workload shape the paged engine's
    shared-prefix block reuse exists for.  The default 0 reproduces the
    fully rid-derived prompts exactly.

    ``source_shape=(source_len, d_model)`` additionally attaches
    per-request source embeddings for the prime families (encdec/vlm):
    rid-seeded gaussian frames/patches whose length varies across
    requests (full, -1, -2 cyclically), so a shared slot pool holds rows
    of different xlen frontiers at once.

    ``priority`` tags every request with an SLO class (a string) or a
    per-request one (a ``rid -> class`` callable).  ``arrival_process``
    replaces the pseudo-Poisson arrivals with a custom process — a
    callable ``(n, rate_per_s, seed) -> arrival times`` (sorted,
    seconds), e.g. the MMPP/bursty builders in ``benchmarks/traces.py``.

    ``model`` tags every request with a multiplexed engine's lane tag (a
    string) or a per-request one (a ``rid -> tag`` callable); the
    default ``None`` leaves requests untagged for single-model engines.
    The defaults reproduce today's traces byte-identically."""
    if not 0 <= shared_prefix_len <= prompt_len:
        raise ValueError(
            f"shared_prefix_len must be in [0, prompt_len={prompt_len}], "
            f"got {shared_prefix_len}")
    if arrival_process is None:
        arr = bt.poisson_arrivals(rate_per_s, n, 0.0, seed)
    else:
        times = list(arrival_process(n, rate_per_s, seed))
        if len(times) != n or any(b < a for a, b in zip(times, times[1:])):
            raise ValueError(
                f"arrival_process must return {n} sorted arrival times, "
                f"got {len(times)}")
        arr = [bt.Request(arrival_s=t, deadline_s=t, rid=rid)
               for rid, t in enumerate(times)]
    cls_of = priority if callable(priority) else (lambda rid: priority)
    mdl_of = model if callable(model) else (lambda rid: model)
    reqs = []
    for a in arr:
        prompt = tuple(
            (1 + (11 * j + 13 * seed) % (vocab - 1))
            if j < shared_prefix_len
            else (1 + (a.rid * 7 + 3 * j) % (vocab - 1))
            for j in range(prompt_len))
        source = None
        if source_shape is not None:
            smax, d = source_shape
            src_len = max(1, smax - a.rid % 3)
            g = np.random.default_rng((seed + 1) * 1_000_003 + a.rid)
            source = g.standard_normal((src_len, d)).astype(np.float32)
        reqs.append(EngineRequest(
            rid=a.rid, prompt=prompt, max_new_tokens=max_new_tokens,
            arrival_s=a.arrival_s,
            deadline_s=(a.arrival_s + deadline_s
                        if deadline_s != float("inf") else float("inf")),
            source=source, priority=cls_of(a.rid), model=mdl_of(a.rid)))
    return reqs
