"""Pallas TPU kernels for quantized matmul — the MXU-era analogue of the
TPU v1 MatrixMultiply → Accumulators → Activate pipeline.

Two kernels:

``qmatmul_w8a8``  int8 × int8 → int32 accumulate → fused dequant + bias +
                  activation → fp out.  The paper-faithful path: both operands
                  8-bit, products accumulated at 32 bit ("4 MiB of 32-bit
                  Accumulators"), nonlinearity applied on the way out of the
                  accumulators ("Activate ... inputs are the Accumulators").

``qmatmul_w8a16`` bf16/f32 activations × int8 weights, dequantized inside the
                  kernel tile-by-tile, fp32 accumulate.  The modern
                  weight-only-quant serving mode; memory-roofline-wise it is
                  the paper's TPU' insight (halve weight bytes → move the
                  memory term) applied at the kernel level.

Dataflow / BlockSpec design (HW adaptation notes):

- Grid is (M/bm, N/bn, K/bk) with K innermost ("arbitrary"); an int32/f32
  accumulator tile lives in VMEM scratch across the K sweep — this is the
  Accumulator bank.  Pallas's automatic pipelining double-buffers the incoming
  weight tiles, playing the role of the 4-tile-deep Weight FIFO.
- Activations stream from the Unified Buffer analogue (VMEM blocks of x);
  weights stream from HBM (Weight Memory).  Ops/weight-byte of one call is
  2·M — matching the paper's operational-intensity definition.
- Block shapes default to MXU-aligned multiples of 128; int8 K-tiles are 256
  wide since 8-bit operands pack 2× per register lane.  Small-M decode
  problems (M = batch, often 8–64) pass bm ∈ {8, 16, 32} GEMV-style row
  tiles instead of padding to 128 rows; `kernels/autotune.py` picks the
  tile per (M, K, N, mode) under the VMEM budget and `ops.py` threads the
  choice through.
- The bias tile is only streamed when a bias exists: the in_specs/operand
  list is built conditionally, so the bias-free path (most serving
  matmuls) saves one VMEM stream per tile.
- Per-output-channel weight scales (1, bn) and a per-tensor (or per-row)
  activation scale are fused into the accumulator drain, together with bias
  and the Activate-unit nonlinearity (ReLU / sigmoid / tanh of the paper, plus
  gelu / silu for the modern archs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer JAX; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

ACTIVATIONS = ("none", "relu", "gelu", "silu", "tanh", "sigmoid")


def _activate(x: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return x * jax.nn.sigmoid(x)
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {activation!r}")


# ---------------------------------------------------------------------------
# w8a8: int8 x int8 -> int32 accumulate -> dequant -> act
# ---------------------------------------------------------------------------

def _w8a8_kernel(x_ref, w_ref, xs_ref, ws_ref, *rest,
                 nk: int, activation: str, out_dtype, has_bias: bool):
    if has_bias:
        b_ref, o_ref, acc_ref = rest
    else:
        b_ref, (o_ref, acc_ref) = None, rest
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32 on the MXU (preferred_element_type drives the
    # 32-bit accumulate, exactly the paper's 16-bit products -> 32-bit acc).
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _drain():
        acc = acc_ref[...].astype(jnp.float32)
        # dequant: per-tensor act scale (scalar) x per-column weight scale.
        out = acc * xs_ref[0, 0] * ws_ref[...]
        if b_ref is not None:
            out = out + b_ref[...]
        o_ref[...] = _activate(out, activation).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "activation", "out_dtype", "interpret"))
def qmatmul_w8a8(x: jax.Array, w: jax.Array, x_scale: jax.Array,
                 w_scale: jax.Array, bias: Optional[jax.Array] = None, *,
                 bm: int = 128, bn: int = 128, bk: int = 256,
                 activation: str = "none", out_dtype=jnp.float32,
                 interpret: bool = False) -> jax.Array:
    """out = act((x_int8 @ w_int8) * x_scale * w_scale + bias).

    x: (M, K) int8.  w: (K, N) int8.  x_scale: scalar ().  w_scale: (N,).
    bias: (N,) fp or None.  M, N, K padded to block multiples by ops.py.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"unpadded shapes {(m, n, k)} vs blocks {(bm, bn, bk)}"
    nk = k // bk

    xs = x_scale.reshape(1, 1).astype(jnp.float32)
    ws = w_scale.reshape(1, n).astype(jnp.float32)
    has_bias = bias is not None

    kernel = functools.partial(
        _w8a8_kernel, nk=nk, activation=activation, out_dtype=out_dtype,
        has_bias=has_bias)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # acts (UB)
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),   # weights (FIFO)
        pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),      # act scale
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),     # col scales
    ]
    operands = (x, w, xs, ws)
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands += (bias.reshape(1, n).astype(jnp.float32),)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],       # Accumulators
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# w8a16: fp acts x int8 weights (dequant in-kernel), fp32 accumulate
# ---------------------------------------------------------------------------

def _w8a16_kernel(x_ref, w_ref, ws_ref, *rest,
                  nk: int, activation: str, out_dtype, has_bias: bool):
    if has_bias:
        b_ref, o_ref, acc_ref = rest
    else:
        b_ref, (o_ref, acc_ref) = None, rest
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Dequantize the resident weight tile once per (j, kk) visit; fp32 MACs.
    w_tile = w_ref[...].astype(jnp.float32) * ws_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _drain():
        out = acc_ref[...]
        if b_ref is not None:
            out = out + b_ref[...]
        o_ref[...] = _activate(out, activation).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "activation", "out_dtype", "interpret"))
def qmatmul_w8a16(x: jax.Array, w: jax.Array, w_scale: jax.Array,
                  bias: Optional[jax.Array] = None, *,
                  bm: int = 128, bn: int = 128, bk: int = 256,
                  activation: str = "none", out_dtype=jnp.bfloat16,
                  interpret: bool = False) -> jax.Array:
    """out = act((x_fp @ dequant(w_int8)) + bias); weight-only quantization.

    x: (M, K) bf16/f32.  w: (K, N) int8.  w_scale: (N,).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        f"unpadded shapes {(m, n, k)} vs blocks {(bm, bn, bk)}"
    nk = k // bk

    ws = w_scale.reshape(1, n).astype(jnp.float32)
    has_bias = bias is not None

    kernel = functools.partial(
        _w8a16_kernel, nk=nk, activation=activation, out_dtype=out_dtype,
        has_bias=has_bias)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
    ]
    operands = (x, w, ws)
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands += (bias.reshape(1, n).astype(jnp.float32),)

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
