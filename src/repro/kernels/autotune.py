"""Kernel tile autotuner — pick (bm, bn, bk) per problem shape.

The paper's performance story is that *fit*, not peak TOPS, decides achieved
throughput: the 256x256 matrix unit runs at 80% of peak only when the
software keeps its pipelines full.  Our Pallas kernels are the same story at
MXU scale — a decode-sized matmul (M = batch, often 8-64) padded up to a
bm=128 tile wastes >75% of every MXU pass, while an over-large K tile blows
the VMEM (Unified-Buffer analogue) budget and stalls the pipeline on
spills.  This module makes the tile choice explicit, modelled, and cached:

1. ``enumerate_candidates`` — every legal (bm, bn, bk) for an (M, K, N,
   quant-mode) problem under hard alignment rules (lane = 128, dtype
   sublane minima) and an explicit VMEM budget: double-buffered x-tile +
   w-tile + scale/bias tiles + output tile, plus the accumulator scratch,
   must fit in ``DEFAULT_VMEM_BUDGET``.
2. ``predicted_cost`` — an analytic roofline of one kernel launch: padded
   flops vs streamed bytes (x is re-streamed per N-tile, w per M-tile —
   the same flops/bytes accounting ``core.hlo_cost`` does structurally),
   plus a per-grid-step dispatch overhead.  Padding waste is penalized
   naturally because the padded problem is what gets executed.
3. ``best_config`` — rank candidates, optionally refine the top few with a
   measured timing backend (TPU only), and persist the winner in a JSON
   cache keyed by (shape, mode, x-dtype, backend) so reruns are free.

Cache file format (``autotune.json``)::

    {"schema_version": 1,
     "entries": {"64x4096x4096|w8a16|bf16|bias|tpu": {
         "bm": 64, "bn": 256, "bk": 512, "source": "measured"}}}

Regenerate by deleting the file (env ``REPRO_AUTOTUNE_CACHE`` overrides the
path; default ``~/.cache/repro_tpu/autotune.json``) — the analytic model
refills it on first use; on a TPU backend the top candidates are re-timed.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Hardware model constants
# ---------------------------------------------------------------------------

LANE = 128                       # last-dim tile width, every dtype
SUBLANE = {"int8": 32, "bf16": 16, "f32": 8}   # min second-to-last dim
DTYPE_BYTES = {"int8": 1, "bf16": 2, "f32": 4}

VMEM_BYTES = 16 * 2 ** 20        # per-core VMEM
# leave headroom for Pallas metadata / semaphores / the compiler's own
# staging buffers; candidates must fit working set in this budget.
DEFAULT_VMEM_BUDGET = 12 * 2 ** 20

MODES = ("w8a8", "w8a16")

BM_CANDIDATES = (8, 16, 32, 64, 128, 256, 512)
BN_CANDIDATES = (128, 256, 512)
BK_CANDIDATES = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class KernelHW:
    """Roofline constants for the analytic cost model (v4-class defaults).

    Only *ratios* matter for ranking; absolute values are not calibrated.
    """
    peak_flops: float = 275e12       # bf16/f32-accum MXU peak
    int8_speedup: float = 2.0        # paper §2: 8-bit ops at double rate
    hbm_bw: float = 1.2e12           # bytes/s
    grid_step_s: float = 3e-7        # per grid-step dispatch overhead


@dataclasses.dataclass(frozen=True)
class TileConfig:
    bm: int
    bn: int
    bk: int

    def as_kwargs(self) -> dict:
        return {"bm": self.bm, "bn": self.bn, "bk": self.bk}


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _round_up(a: int, b: int) -> int:
    return _cdiv(a, b) * b


def x_dtype_for(mode: str, act_dtype: str = "bf16") -> str:
    """The streamed-activation dtype of a quant mode."""
    return "int8" if mode == "w8a8" else act_dtype


# ---------------------------------------------------------------------------
# VMEM working-set model
# ---------------------------------------------------------------------------

def vmem_bytes(cfg: TileConfig, *, mode: str, x_dtype: str = "bf16",
               has_bias: bool = True, out_dtype: str = "f32") -> int:
    """Working-set bytes of one kernel step with double-buffered streams.

    Pallas pipelines every BlockSpec operand (and the output) with two
    buffers — the Weight-FIFO analogue — so streamed tiles count twice;
    the accumulator scratch is single-buffered and persistent.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}")
    xb = DTYPE_BYTES["int8"] if mode == "w8a8" else DTYPE_BYTES[x_dtype]
    x_tile = cfg.bm * cfg.bk * xb
    w_tile = cfg.bk * cfg.bn * DTYPE_BYTES["int8"]
    scales = cfg.bn * 4 + (4 if mode == "w8a8" else 0)   # col scales (+act)
    bias = cfg.bn * 4 if has_bias else 0
    out_tile = cfg.bm * cfg.bn * DTYPE_BYTES[out_dtype]
    acc = cfg.bm * cfg.bn * 4                            # int32 / f32 scratch
    return 2 * (x_tile + w_tile + scales + bias + out_tile) + acc


def _bm_align(mode: str, x_dtype: str, out_dtype: str) -> int:
    """bm alignment: both the streamed x tile (bm, bk) and the output tile
    (bm, bn) must be legal — the stricter sublane floor wins."""
    return max(SUBLANE[x_dtype_for(mode, x_dtype)], SUBLANE[out_dtype])


def is_legal(cfg: TileConfig, *, mode: str, x_dtype: str = "bf16",
             out_dtype: str = "f32", has_bias: bool = True,
             budget: int = DEFAULT_VMEM_BUDGET) -> bool:
    """Alignment + budget legality of a tile config (shape-independent).

    - bm must honour the sublane minimum of BOTH the streamed x dtype and
      the output dtype (int8 32, bf16 16, f32 8) — the (bm, bn) out tile
      is a real block too;
    - bn / bk must be lane-aligned (128); w8a8 K-tiles additionally pack
      two int8 per register lane, so bk must be a multiple of 256;
    - the double-buffered working set must fit the VMEM budget.
    """
    if cfg.bm <= 0 or cfg.bm % _bm_align(mode, x_dtype, out_dtype) != 0:
        return False
    if cfg.bn % LANE != 0 or cfg.bk % LANE != 0:
        return False
    if mode == "w8a8" and cfg.bk % 256 != 0:
        return False
    return vmem_bytes(cfg, mode=mode, x_dtype=x_dtype, out_dtype=out_dtype,
                      has_bias=has_bias) <= budget


def enumerate_candidates(m: int, k: int, n: int, *, mode: str = "w8a16",
                         x_dtype: str = "bf16", out_dtype: str = "f32",
                         has_bias: bool = True,
                         budget: int = DEFAULT_VMEM_BUDGET
                         ) -> List[TileConfig]:
    """All legal (bm, bn, bk) for a problem, pruned of dominated padding.

    A block strictly larger than the smallest block covering the whole
    dimension only adds padding (same grid extent of 1), so at most one
    such candidate per dimension survives.
    """

    def axis_pool(cands: Sequence[int], size: int, align: int) -> List[int]:
        pool = [c for c in cands if c % align == 0]
        # keep blocks that don't exceed the padded dim, plus the single
        # smallest block that covers the dim entirely
        keep = [c for c in pool if c < _round_up(size, align) * 2]
        covering = [c for c in pool if c >= size]
        if covering and min(covering) not in keep:
            keep.append(min(covering))
        return sorted(set(keep)) or [min(pool)]

    bms = axis_pool(BM_CANDIDATES, m, _bm_align(mode, x_dtype, out_dtype))
    bns = axis_pool(BN_CANDIDATES, n, LANE)
    bk_align = 256 if mode == "w8a8" else LANE
    bks = axis_pool(BK_CANDIDATES, k, bk_align)
    out = []
    for bm in bms:
        for bn in bns:
            for bk in bks:
                cfg = TileConfig(bm, bn, bk)
                if is_legal(cfg, mode=mode, x_dtype=x_dtype,
                            out_dtype=out_dtype, has_bias=has_bias,
                            budget=budget):
                    out.append(cfg)
    return out


# ---------------------------------------------------------------------------
# Analytic cost model
# ---------------------------------------------------------------------------

def predicted_cost(m: int, k: int, n: int, cfg: TileConfig, *,
                   mode: str = "w8a16", x_dtype: str = "bf16",
                   out_dtype: str = "f32",
                   hw: KernelHW = KernelHW()) -> float:
    """Modelled seconds for one kernel launch at this tile config.

    flops/bytes accounting mirrors ``core.hlo_cost``: the *padded* problem
    is what executes, x tiles are re-streamed once per N-tile column, w
    tiles once per M-tile row, and the roofline max of compute vs memory
    time plus a per-grid-step overhead ranks the candidates.
    """
    xd = x_dtype_for(mode, x_dtype)
    mp = _round_up(m, cfg.bm)
    kp = _round_up(k, cfg.bk)
    np_ = _round_up(n, cfg.bn)
    gi, gj, gk = mp // cfg.bm, np_ // cfg.bn, kp // cfg.bk

    flops = 2.0 * mp * kp * np_
    peak = hw.peak_flops * (hw.int8_speedup if mode == "w8a8" else 1.0)
    flop_time = flops / peak

    x_bytes = mp * kp * DTYPE_BYTES[xd] * gj        # x streamed per N tile
    w_bytes = kp * np_ * DTYPE_BYTES["int8"] * gi   # w streamed per M tile
    s_bytes = np_ * 4 * gi                          # col scales per M tile
    o_bytes = mp * np_ * DTYPE_BYTES[out_dtype]
    mem_time = (x_bytes + w_bytes + s_bytes + o_bytes) / hw.hbm_bw

    return max(flop_time, mem_time) + gi * gj * gk * hw.grid_step_s


def rank_candidates(m: int, k: int, n: int, *, mode: str = "w8a16",
                    x_dtype: str = "bf16", out_dtype: str = "f32",
                    has_bias: bool = True,
                    budget: int = DEFAULT_VMEM_BUDGET,
                    hw: KernelHW = KernelHW()) -> List[TileConfig]:
    """Legal candidates sorted best-first by the analytic model."""
    cands = enumerate_candidates(m, k, n, mode=mode, x_dtype=x_dtype,
                                 out_dtype=out_dtype, has_bias=has_bias,
                                 budget=budget)
    if not cands:
        raise ValueError(
            f"no legal tile config for {(m, k, n)} mode={mode} under "
            f"budget {budget}")
    return sorted(cands, key=lambda c: predicted_cost(
        m, k, n, c, mode=mode, x_dtype=x_dtype, out_dtype=out_dtype,
        hw=hw))


# ---------------------------------------------------------------------------
# Persistent JSON cache
# ---------------------------------------------------------------------------

SCHEMA_VERSION = 1


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return os.path.expanduser(env)
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_tpu",
                        "autotune.json")


class AutotuneCache:
    """JSON-backed (shape, mode, dtype, backend) -> TileConfig store.

    Concurrency discipline (same as ``checkpoint/manager.py``): every
    write goes to a same-directory ``*.tmp`` that is flushed, fsync'd and
    ``os.replace``d into place, so a reader can never observe a torn
    file.  Before replacing, the entries already on disk are re-read and
    merged, under the in-process lock plus a best-effort ``flock`` on a
    ``.lock`` sidecar — so two bench processes tuning different shapes
    keep each other's winners (last writer wins only on identical keys;
    where ``flock`` is unavailable the merge still narrows the lost-
    update window to the read-merge-replace itself).  A torn or
    stale-schema file on disk is discarded, not fatal — the analytic
    model refills it.  Writes are tolerated to fail on read-only
    filesystems; the cache is an accelerator, not a dependency.
    ``AutotuneCache(path="")`` gives a purely in-memory cache (tests).
    """

    def __init__(self, path: Optional[str] = None):
        self.path = default_cache_path() if path is None else path
        self._lock = threading.Lock()
        self._entries: Optional[Dict[str, dict]] = None

    @staticmethod
    def key(m: int, k: int, n: int, mode: str, x_dtype: str,
            out_dtype: str, has_bias: bool, backend: str) -> str:
        bias = "bias" if has_bias else "nobias"
        return f"{m}x{k}x{n}|{mode}|{x_dtype}>{out_dtype}|{bias}|{backend}"

    def _read_disk(self) -> Dict[str, dict]:
        """Entries currently on disk; {} for missing/torn/stale files."""
        try:
            with open(self.path) as f:
                data = json.load(f)
            if data.get("schema_version") == SCHEMA_VERSION:
                return dict(data.get("entries", {}))
        except (OSError, ValueError):
            pass
        return {}

    def _load(self) -> Dict[str, dict]:
        if self._entries is None:
            self._entries = self._read_disk() if self.path else {}
        return self._entries

    def get(self, key: str) -> Optional[TileConfig]:
        with self._lock:
            e = self._load().get(key)
        if not e:
            return None
        return TileConfig(int(e["bm"]), int(e["bn"]), int(e["bk"]))

    def put(self, key: str, cfg: TileConfig, source: str = "analytic"
            ) -> None:
        with self._lock:
            entries = self._load()
            entries[key] = {"bm": cfg.bm, "bn": cfg.bn, "bk": cfg.bk,
                            "source": source}
            if not self.path:               # in-memory only
                return
            try:
                d = os.path.dirname(self.path)
                if d:
                    os.makedirs(d, exist_ok=True)
                lockf = None
                try:                         # cross-PROCESS exclusion
                    import fcntl
                    lf = open(self.path + ".lock", "w")
                    try:
                        fcntl.flock(lf, fcntl.LOCK_EX)
                        lockf = lf
                    except OSError:          # e.g. ENOLCK on NFS
                        lf.close()
                except (ImportError, OSError):
                    pass                     # best-effort: merge below
                try:
                    # merge whatever landed on disk since we loaded, so
                    # a concurrent bench run's winners survive this write
                    merged = self._read_disk()
                    merged.update(entries)
                    self._entries = entries = merged
                    fd, tmp = tempfile.mkstemp(dir=d or ".", suffix=".tmp")
                    with os.fdopen(fd, "w") as f:
                        json.dump({"schema_version": SCHEMA_VERSION,
                                   "entries": entries}, f, indent=1)
                        f.flush()
                        os.fsync(f.fileno())
                    os.replace(tmp, self.path)
                finally:
                    if lockf is not None:
                        lockf.close()        # releases the flock
            except OSError:
                pass                         # read-only fs: stay in-memory


_default_cache: Optional[AutotuneCache] = None


def get_cache() -> AutotuneCache:
    global _default_cache
    if _default_cache is None:
        _default_cache = AutotuneCache()
    return _default_cache


# ---------------------------------------------------------------------------
# Timing backend (measured refinement, TPU only)
# ---------------------------------------------------------------------------

def measure_config(m: int, k: int, n: int, cfg: TileConfig, *,
                   mode: str = "w8a16", iters: int = 5) -> float:
    """Wall-clock one kernel launch at this config (compiled backends only;
    interpret-mode timings are meaningless).  Returns seconds/call."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import qmatmul as _k

    mp = _round_up(m, cfg.bm)
    kp = _round_up(k, cfg.bk)
    np_ = _round_up(n, cfg.bn)
    key = jax.random.PRNGKey(0)
    ws = jnp.ones((np_,), jnp.float32)
    w = jax.random.randint(key, (kp, np_), -127, 127, jnp.int8)
    if mode == "w8a8":
        x = jax.random.randint(jax.random.fold_in(key, 1), (mp, kp),
                               -127, 127, jnp.int8)
        fn = lambda: _k.qmatmul_w8a8(x, w, jnp.ones((), jnp.float32), ws,
                                     None, **cfg.as_kwargs())
    else:
        x = jax.random.normal(jax.random.fold_in(key, 1), (mp, kp),
                              jnp.float32).astype(jnp.bfloat16)
        fn = lambda: _k.qmatmul_w8a16(x, w, ws, None, **cfg.as_kwargs())
    fn().block_until_ready()                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def best_config(m: int, k: int, n: int, *, mode: str = "w8a16",
                x_dtype: str = "bf16", out_dtype: str = "f32",
                has_bias: bool = True,
                budget: int = DEFAULT_VMEM_BUDGET,
                backend: Optional[str] = None,
                measure: Optional[Callable[[TileConfig], float]] = None,
                top_k_measure: int = 4,
                cache: Optional[AutotuneCache] = None,
                hw: KernelHW = KernelHW()) -> TileConfig:
    """Tuned (bm, bn, bk) for a problem; cached per (shape, mode, dtype,
    backend).

    ``measure``: optional ``config -> seconds`` timing backend.  When given
    (or when running on a real TPU backend, where ``measure_config`` is
    used automatically), the top ``top_k_measure`` analytic candidates are
    re-ranked by measurement.  Offline the analytic ranking decides alone.
    """
    if backend is None:
        try:
            import jax
            backend = jax.default_backend()
        except Exception:               # pragma: no cover - defensive
            backend = "cpu"
    cache = cache or get_cache()
    key = AutotuneCache.key(m, k, n, mode, x_dtype, out_dtype, has_bias,
                            backend)
    hit = cache.get(key)
    if hit is not None:
        return hit

    ranked = rank_candidates(m, k, n, mode=mode, x_dtype=x_dtype,
                             out_dtype=out_dtype, has_bias=has_bias,
                             budget=budget, hw=hw)
    if measure is None and backend == "tpu":
        measure = lambda c: measure_config(m, k, n, c, mode=mode)
    source = "analytic"
    winner = ranked[0]
    if measure is not None:
        timed = []
        for c in ranked[:top_k_measure]:
            try:
                timed.append((measure(c), c))
            except Exception:            # candidate failed to compile/run
                continue
        if timed:
            winner = min(timed, key=lambda t: t[0])[1]
            source = "measured"
    cache.put(key, winner, source=source)
    return winner


def arch_matmul_problems(cfg, m: int) -> List[Tuple[str, int, int, int]]:
    """The serving-path matmul problems of an ArchConfig at row count m.

    Rows are (name, M, K, N) — the projections every decode/prefill step
    runs through ``qlinear.linear``.  Used by the registry-wide budget
    tests and the bench's chosen-tiles report.
    """
    d = cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    rows = [
        ("wq", m, d, h * hd),
        ("wk", m, d, kv * hd),
        ("wv", m, d, kv * hd),
        ("wo", m, h * hd, d),
        ("w_up", m, d, cfg.d_ff),
        ("w_down", m, cfg.d_ff, d),
        ("unembed", m, d, cfg.vocab),
    ]
    if cfg.gated_mlp:
        rows.insert(5, ("w_gate", m, d, cfg.d_ff))
    return rows


def tune_arch(cfg, *, m_values: Sequence[int] = (8, 32, 128),
              modes: Sequence[str] = ("w8a16", "w8a8"),
              budget: int = DEFAULT_VMEM_BUDGET,
              cache: Optional[AutotuneCache] = None) -> List[dict]:
    """Tune every serving matmul of an arch at several decode/prefill row
    counts.  Returns report rows (consumed by benchmarks and tests)."""
    out = []
    for m in m_values:
        for name, mm, kk, nn in arch_matmul_problems(cfg, m):
            for mode in modes:
                # production serving dtypes: bf16 activations in and out
                tc = best_config(mm, kk, nn, mode=mode, x_dtype="bf16",
                                 out_dtype="bf16", budget=budget,
                                 cache=cache)
                out.append({
                    "op": name, "arch": cfg.name, "m": mm, "k": kk, "n": nn,
                    "mode": mode, "bm": tc.bm, "bn": tc.bn, "bk": tc.bk,
                    "vmem_bytes": vmem_bytes(tc, mode=mode,
                                             out_dtype="bf16"),
                })
    return out
