"""Pallas TPU fused decode attention over an int8 KV cache.

The dominant decode memory term today is the *materialized dequantized
cache*: the XLA einsum path streams the int8 cache, widens it to bf16/f32
in HBM-visible intermediates, and pays the traffic twice.  This kernel
reads the int8 cache tiles directly into VMEM and dequantizes tile-by-tile
on the way into the MXU — HBM traffic is exactly q + int8 K + int8 V +
fp32 scales + out, the paper's Unified-Buffer discipline applied to the
serving hot loop.

Shapes (native cache layout, no transposes):

  q         (B, KV, G, hd)    fp — one query token, grouped per KV head
  k, v      (B, S, KV, hd)    int8 cache slots
  k_scale,  (B, S, KV)        fp32 per-(token, head) dequant scales
  v_scale
  valid_len (B, 1)            int32 — slots < valid_len[b] participate
                              (per-row: the slot engine's requests each
                              sit at their own sequence frontier)
  k_new,    (B, 1, KV, hd)    fp — OPTIONAL: the current token's k/v
  v_new                       (append path: the cache holds only tokens
                              < valid_len; the new token rides along as
                              one extra operand instead of a cache
                              rewrite inside the layer scan)
  out       (B, KV, G, hd)    fp

Grid: (B, KV, S/blk_s) with the slot sweep innermost ("arbitrary");
scratch carries the online-softmax state (acc[G, hd] f32, m[G] f32,
l[G] f32) across the sweep, like the flash kernel.  Per-token scales are
independent of the contracted hd axis, so they fold into score columns
(k_scale) and prob columns (v_scale) instead of dequantizing K/V tiles
into a widened copy — only the (blk_s, hd) tile ever exists at fp32, in
VMEM, for the duration of one dot.  With ``k_new``/``v_new`` the final
sweep step folds the current token into the online softmax as one more
score column before normalizing — closing the append path that the
einsum fallback previously served alone.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer JAX; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, *rest,
                        ns: int, blk_s: int, sm_scale: float, out_dtype,
                        has_new: bool):
    if has_new:
        kn_ref, vn_ref, vl_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        vl_ref, o_ref, acc_ref, m_ref, l_ref = rest
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (blk_s, hd) int8 -> f32
    ks = ks_ref[0, :, 0]                           # (blk_s,) f32
    # q·(k*ks) == (q·k)*ks — the per-token scale is constant along hd, so
    # dequant folds into the score column instead of a widened K tile.
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale * ks[None, :]

    slot = sb * blk_s + jax.lax.broadcasted_iota(jnp.int32, (1, blk_s), 1)
    valid = slot < vl_ref[0, 0]                    # (1, blk_s)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                            # (G,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    vs = vs_ref[0, :, 0]                           # (blk_s,) f32
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (blk_s, hd) int8 -> f32
    # fold v_scale into prob columns: p·(v*vs) == (p*vs)·v
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p * vs[None, :], v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sb == ns - 1)
    def _final():
        acc, m_run, l_run = acc_ref[...], m_ref[...], l_ref[...]
        if has_new:
            # Append path: fold the current token's k/v (already fp — the
            # caller dequantized its own-step quantization) into the online
            # softmax as one extra column.  Also covers the empty-cache
            # tick: every slot masked -> m_run = -inf -> alpha underflows
            # to 0 and the output is exactly the new token's v.
            kn = kn_ref[0, 0, 0, :].astype(jnp.float32)      # (hd,)
            vn = vn_ref[0, 0, 0, :].astype(jnp.float32)      # (hd,)
            s_new = jnp.sum(q * kn[None, :], axis=1) * sm_scale   # (G,)
            m_fin = jnp.maximum(m_run, s_new)
            alpha_f = jnp.exp(m_run - m_fin)
            p_new = jnp.exp(s_new - m_fin)
            l_run = l_run * alpha_f + p_new
            acc = acc * alpha_f[:, None] + p_new[:, None] * vn[None, :]
        denom = jnp.maximum(l_run, 1e-30)[:, None]
        o_ref[0, 0] = (acc / denom).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "blk_s", "sm_scale", "out_dtype", "interpret"))
def decode_attention_int8(q: jax.Array, k: jax.Array, ks: jax.Array,
                          v: jax.Array, vs: jax.Array,
                          valid_len: jax.Array,
                          k_new=None, v_new=None, *, blk_s: int = 128,
                          sm_scale: float, out_dtype=jnp.float32,
                          interpret: bool = False) -> jax.Array:
    """One-token attention against an int8 KV cache (padded shapes).

    q (B, KV, G, hd) fp; k/v (B, S, KV, hd) int8; ks/vs (B, S, KV) f32;
    valid_len () or (B,) int32.  ``k_new``/``v_new`` (B, 1, KV, hd) fp:
    the append path's current-token k/v, folded in at the final sweep
    step.  G must be sublane-aligned (>= 8), hd lane-aligned (128
    multiple), S a multiple of blk_s — `ops.decode_attention` pads.
    """
    b, kvh, g, hd = q.shape
    s_slots = k.shape[1]
    assert s_slots % blk_s == 0, (s_slots, blk_s)
    assert (k_new is None) == (v_new is None)
    ns = s_slots // blk_s
    has_new = k_new is not None

    kernel = functools.partial(
        _decode_attn_kernel, ns=ns, blk_s=blk_s, sm_scale=sm_scale,
        out_dtype=out_dtype, has_new=has_new)
    vl = jnp.broadcast_to(jnp.asarray(valid_len).reshape(-1), (b,))
    vl = vl.reshape(b, 1).astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si: (bi, ki, 0, 0)),
        pl.BlockSpec((1, blk_s, 1, hd),
                     lambda bi, ki, si: (bi, si, ki, 0)),
        pl.BlockSpec((1, blk_s, 1), lambda bi, ki, si: (bi, si, ki)),
        pl.BlockSpec((1, blk_s, 1, hd),
                     lambda bi, ki, si: (bi, si, ki, 0)),
        pl.BlockSpec((1, blk_s, 1), lambda bi, ki, si: (bi, si, ki)),
    ]
    operands = [q, k, ks, v, vs]
    if has_new:
        in_specs += [
            pl.BlockSpec((1, 1, 1, hd), lambda bi, ki, si: (bi, 0, ki, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda bi, ki, si: (bi, 0, ki, 0)),
        ]
        operands += [k_new, v_new]
    in_specs.append(pl.BlockSpec((1, 1), lambda bi, ki, si: (bi, 0)))
    operands.append(vl)

    return pl.pallas_call(
        kernel,
        grid=(b, kvh, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, si: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),      # running context acc
            pltpu.VMEM((g,), jnp.float32),         # running max
            pltpu.VMEM((g,), jnp.float32),         # running denominator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "out_dtype", "interpret"))
def decode_attention_int8_paged(q: jax.Array, k: jax.Array, ks: jax.Array,
                                v: jax.Array, vs: jax.Array,
                                valid_len: jax.Array,
                                block_tables: jax.Array,
                                k_new=None, v_new=None, *,
                                sm_scale: float, out_dtype=jnp.float32,
                                interpret: bool = False) -> jax.Array:
    """Paged variant: the same online-softmax sweep, but K/V tiles are
    physical KV blocks gathered through a per-row block table.

    q (B, KV, G, hd) fp; k/v (NB, bs, KV, hd) int8 physical blocks;
    ks/vs (NB, bs, KV) f32; block_tables (B, MB) int32; valid_len () or
    (B,) int32 counts LOGICAL positions.  ``k_new``/``v_new``
    (B, 1, KV, hd) fp: the current token's k/v (the engine scatters the
    new entry into its block after attention, so the cache holds tokens
    < valid_len and the new token rides as the append column).

    The table is a scalar-prefetch operand (PrefetchScalarGridSpec): grid
    step (bi, ki, si) streams block ``block_tables[bi, si]`` — the sweep
    that already walked contiguous slot tiles now walks table entries, so
    the kernel body is reused unchanged with ns=MB, blk_s=bs (its
    position mask ``si*bs + i < valid_len`` is logical-position math
    either way).  Entries past a row's frontier point at the reserved
    trash block 0; their finite garbage is masked exactly like padding.
    """
    b, kvh, g, hd = q.shape
    bs = k.shape[1]
    mb = block_tables.shape[1]
    assert (k_new is None) == (v_new is None)
    has_new = k_new is not None

    def kernel(tbl_ref, *refs):
        del tbl_ref    # consumed by the index maps below
        _decode_attn_kernel(*refs, ns=mb, blk_s=bs, sm_scale=sm_scale,
                            out_dtype=out_dtype, has_new=has_new)

    vl = jnp.broadcast_to(jnp.asarray(valid_len).reshape(-1), (b,))
    vl = vl.reshape(b, 1).astype(jnp.int32)

    in_specs = [
        pl.BlockSpec((1, 1, g, hd), lambda bi, ki, si, tbl: (bi, ki, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd),
                     lambda bi, ki, si, tbl: (tbl[bi, si], 0, ki, 0)),
        pl.BlockSpec((1, bs, 1),
                     lambda bi, ki, si, tbl: (tbl[bi, si], 0, ki)),
        pl.BlockSpec((1, bs, 1, hd),
                     lambda bi, ki, si, tbl: (tbl[bi, si], 0, ki, 0)),
        pl.BlockSpec((1, bs, 1),
                     lambda bi, ki, si, tbl: (tbl[bi, si], 0, ki)),
    ]
    operands = [q, k, ks, v, vs]
    if has_new:
        in_specs += [
            pl.BlockSpec((1, 1, 1, hd),
                         lambda bi, ki, si, tbl: (bi, 0, ki, 0)),
            pl.BlockSpec((1, 1, 1, hd),
                         lambda bi, ki, si, tbl: (bi, 0, ki, 0)),
        ]
        operands += [k_new, v_new]
    in_specs.append(pl.BlockSpec((1, 1), lambda bi, ki, si, tbl: (bi, 0)))
    operands.append(vl)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kvh, mb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, si, tbl: (bi, ki, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),      # running context acc
            pltpu.VMEM((g,), jnp.float32),         # running max
            pltpu.VMEM((g,), jnp.float32),         # running denominator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), out_dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, *operands)
