"""Pallas TPU flash attention — fused scores/softmax/context.

The §Roofline analysis shows every prefill/train cell paying HBM traffic
for materialized (blk_q, Skv) probability tiles (the pure-XLA chunked
attention).  This kernel keeps the running max/sum/accumulator in VMEM
scratch across the KV-block sweep (online softmax), so HBM traffic is just
Q + K + V + O — the flash-attention memory discipline, which is also the
paper's Unified-Buffer philosophy: keep intermediates on chip, stream only
what must move.

Grid: (BH, n_q_blocks, n_kv_blocks), KV innermost ("arbitrary"); scratch
carries (acc[blk_q, hd] f32, m[blk_q] f32, l[blk_q] f32) across the KV
sweep, exactly like the int8 matmul kernel carries its accumulator tile.
Causal/window masking is applied per element; fully-masked KV blocks are
cheap (they still stream K/V — block-level skipping is a further TPU
optimization, noted in EXPERIMENTS).

Block shapes default to MXU-aligned (128) tiles; `ops.flash_attention`
pads ragged shapes and reshapes (B, S, H, hd) <-> (B*H, S, hd).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer JAX; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  nk: int, blk_q: int, blk_k: int, sm_scale: float,
                  causal: bool, window: Optional[int], kv_len: int,
                  out_dtype):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                  # (blk_q, hd)
    k = k_ref[0].astype(jnp.float32)                  # (blk_k, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                 (blk_q, blk_k), 0)
    kpos = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                 (blk_q, blk_k), 1)
    mask = kpos < kv_len                              # padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # (blk_q,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0].astype(jnp.float32)                  # (blk_k, hd)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _final():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(jax.jit, static_argnames=(
    "blk_q", "blk_k", "causal", "window", "kv_len", "sm_scale",
    "out_dtype", "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         blk_q: int = 128, blk_k: int = 128,
                         causal: bool = True,
                         window: Optional[int] = None,
                         kv_len: Optional[int] = None,
                         sm_scale: Optional[float] = None,
                         out_dtype=jnp.bfloat16,
                         interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd) — padded to block multiples.

    ``kv_len``: number of valid KV positions (<= Skv, for padded inputs).
    ``sm_scale``: softmax scale — pass the ORIGINAL hd**-0.5 when the head
    dim was zero-padded to the 128 lane width.
    """
    bh, sq, hd = q.shape
    _, skv, _ = k.shape
    assert sq % blk_q == 0 and skv % blk_k == 0, (sq, skv)
    nq, nk = sq // blk_q, skv // blk_k
    kv_len = skv if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel, nk=nk, blk_q=blk_q, blk_k=blk_k,
        sm_scale=sm_scale if sm_scale is not None else hd ** -0.5,
        causal=causal, window=window,
        kv_len=kv_len, out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, hd), jnp.float32),   # running context acc
            pltpu.VMEM((blk_q,), jnp.float32),      # running max
            pltpu.VMEM((blk_q,), jnp.float32),      # running denominator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
