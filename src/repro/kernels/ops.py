"""Public jit'd wrappers around the Pallas quantized-matmul kernels.

Responsibilities:
- accept ND activations (leading dims flattened to M),
- resolve tile shapes: explicit (bm, bn, bk) overrides win, otherwise
  `kernels.autotune.best_config` picks tuned tiles per (M, K, N, mode) —
  small-M decode problems get GEMV-style bm ∈ {8, 16, 32} row tiles
  instead of padding to 128 rows,
- pad M/N/K up to the chosen block multiples and slice the result back,
- dispatch: TPU backend -> compiled Pallas kernel; CPU -> the jnp oracle
  (numerically identical contract) unless ``interpret=True`` is forced, which
  runs the actual kernel body through the Pallas interpreter for validation,
- integrate with `repro.core.quant.QTensor`.

This is the only module model code should import from kernels/.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize
from repro.kernels import autotune as _at
from repro.kernels import qmatmul as _k
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - defensive
        return False


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _dtype_name(dtype) -> str:
    return "f32" if dtype == jnp.float32 else "bf16"


def _resolve_blocks(m: int, k: int, n: int, *, mode: str, x_dtype: str,
                    out_dtype: str, has_bias: bool, bm: Optional[int],
                    bn: Optional[int], bk: Optional[int]):
    """Fill unspecified block dims from the autotuner (explicit args win).

    Runs at trace time on static shapes: the tuned choice is a Python int
    baked into the compiled kernel, and the JSON cache makes reruns free.
    """
    if bm is not None and bn is not None and bk is not None:
        return bm, bn, bk
    tc = _at.best_config(m, k, n, mode=mode, x_dtype=x_dtype,
                         out_dtype=out_dtype, has_bias=has_bias)
    return bm or tc.bm, bn or tc.bn, bk or tc.bk


@functools.partial(jax.jit, static_argnames=(
    "activation", "out_dtype", "interpret", "bm", "bn", "bk"))
def qmatmul(x, w: QTensor, bias: Optional[jax.Array] = None, *,
            x_q: Optional[QTensor] = None, activation: str = "none",
            out_dtype=jnp.bfloat16, interpret: bool = False,
            bm: Optional[int] = None, bn: Optional[int] = None,
            bk: Optional[int] = None) -> jax.Array:
    """act((x @ dequant(w)) + bias) with int8 weights.

    ``x`` fp array of shape (..., K); ``w`` QTensor (K, N) with per-column
    scales.  If ``x_q`` is given (pre-quantized activations, per-tensor
    scale), the full w8a8 integer path runs; otherwise weight-only w8a16.
    ``bm``/``bn``/``bk`` override the autotuned tile shape when given.
    """
    if not isinstance(w, QTensor):
        raise TypeError("w must be a QTensor; quantize with quantize_weight()")
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]

    w_scale = w.scale.reshape(-1)
    use_pallas = _on_tpu() or interpret
    run_interp = interpret and not _on_tpu()

    if x_q is not None:
        xq2 = x_q.values.reshape(-1, kdim)
        xs = x_q.scale.reshape(())
        if use_pallas:
            bm, bn, bk = _resolve_blocks(
                m, kdim, n, mode="w8a8", x_dtype="int8",
                out_dtype=_dtype_name(out_dtype),
                has_bias=bias is not None, bm=bm, bn=bn, bk=bk)
            xp = _pad_to(_pad_to(xq2, bm, 0), bk, 1)
            wp = _pad_to(_pad_to(w.values, bk, 0), bn, 1)
            wsp = _pad_to(w_scale, bn, 0)
            bp = _pad_to(bias, bn, 0) if bias is not None else None
            out = _k.qmatmul_w8a8(
                xp, wp, xs, wsp, bp, bm=bm, bn=bn, bk=bk,
                activation=activation, out_dtype=out_dtype,
                interpret=run_interp)
            return out[:m, :n].reshape(*lead, n)
        out = _ref.qmatmul_w8a8_ref(
            xq2, w.values, xs, w_scale, bias,
            activation=activation, out_dtype=out_dtype)
        return out.reshape(*lead, n)

    if use_pallas:
        bm, bn, bk = _resolve_blocks(
            m, kdim, n, mode="w8a16", x_dtype=_dtype_name(x.dtype),
            out_dtype=_dtype_name(out_dtype),
            has_bias=bias is not None, bm=bm, bn=bn, bk=bk)
        xp = _pad_to(_pad_to(x2, bm, 0), bk, 1)
        wp = _pad_to(_pad_to(w.values, bk, 0), bn, 1)
        wsp = _pad_to(w_scale, bn, 0)
        bp = _pad_to(bias, bn, 0) if bias is not None else None
        out = _k.qmatmul_w8a16(
            xp, wp, wsp, bp, bm=bm, bn=bn, bk=bk,
            activation=activation, out_dtype=out_dtype,
            interpret=run_interp)
        return out[:m, :n].reshape(*lead, n)
    out = _ref.qmatmul_w8a16_ref(
        x2, w.values, w_scale, bias,
        activation=activation, out_dtype=out_dtype)
    return out.reshape(*lead, n)


def qmatmul_dynamic(x, w: QTensor, bias=None, *, activation: str = "none",
                    out_dtype=jnp.bfloat16, interpret: bool = False):
    """w8a8 with on-the-fly per-tensor activation quantization (the TPU's
    quantize-on-entry-to-UB behaviour)."""
    x_q = quantize(x.astype(jnp.float32), bits=8, axis=None)
    return qmatmul(x, w, bias, x_q=x_q, activation=activation,
                   out_dtype=out_dtype, interpret=interpret)


def decode_attention(q, k, v, k_scale, v_scale, valid_len, *,
                     block_tables=None, k_new=None, v_new=None,
                     blk_s: int = 128, out_dtype=jnp.float32,
                     interpret: bool = False):
    """Fused one-token attention against an int8 KV cache.

    q: (B, KV, G, hd) fp — current token's queries grouped per KV head;
    k, v: (B, S, KV, hd) int8 cache; k_scale, v_scale: (B, S, KV) or
    (B, S, KV, 1) fp32 per-(token, head) scales; valid_len: () or (B,)
    int32 (per-row frontiers for the slot engine).

    ``k_new``/``v_new`` (B, 1, KV, hd) or (B, KV, hd) fp: the current
    token's k/v for the append path — the cache then holds only tokens
    < valid_len and the new token joins the softmax as one extra operand
    column inside the kernel (no cache rewrite inside the layer scan).

    ``block_tables`` (B, MB) int32 switches to the PAGED cache layout:
    k/v become physical blocks (NB, bs, KV, hd) (scales (NB, bs, KV) or
    (NB, bs, KV, 1)) and the kernel gathers each row's tiles through its
    table via scalar prefetch; valid_len still counts logical positions.

    TPU (or ``interpret=True``) -> the Pallas kernel, which dequantizes
    tile-by-tile in VMEM; CPU -> the dense jnp oracle (identical math).
    Padding: G to the 8-sublane floor, hd to the 128 lane width, S to a
    blk_s multiple (padded slots are masked by ``valid_len``).
    """
    b, kvh, g, hd = q.shape
    sm_scale = hd ** -0.5
    if (k_new is None) != (v_new is None):
        raise ValueError("k_new and v_new must be passed together")
    if k_new is not None:
        k_new = k_new.reshape(b, 1, kvh, hd)
        v_new = v_new.reshape(b, 1, kvh, hd)
    use_pallas = _on_tpu() or interpret
    if block_tables is not None:
        nb, bs = k.shape[0], k.shape[1]
        ks = k_scale.reshape(nb, bs, kvh)
        vs = v_scale.reshape(nb, bs, kvh)
        if not use_pallas:
            return _ref.decode_attention_paged_ref(
                q, k, v, ks, vs, valid_len, block_tables,
                k_new=k_new, v_new=v_new, sm_scale=sm_scale,
                out_dtype=out_dtype)
        sub = 8 if q.dtype == jnp.float32 else 16
        gp = max(sub, -(-g // sub) * sub)
        qp = _pad_to(_pad_to(q, gp, 2), 128, 3)
        kp = _pad_to(k, 128, 3)
        vp = _pad_to(v, 128, 3)
        knp = _pad_to(k_new, 128, 3) if k_new is not None else None
        vnp = _pad_to(v_new, 128, 3) if v_new is not None else None
        from repro.kernels import decode_attention as _da
        out = _da.decode_attention_int8_paged(
            qp, kp, ks, vp, vs, jnp.asarray(valid_len),
            jnp.asarray(block_tables, jnp.int32), knp, vnp,
            sm_scale=sm_scale, out_dtype=out_dtype,
            interpret=interpret and not _on_tpu())
        return out[:, :, :g, :hd]
    s_slots = k.shape[1]
    ks = k_scale.reshape(b, s_slots, kvh)
    vs = v_scale.reshape(b, s_slots, kvh)
    if not use_pallas:
        out = _ref.decode_attention_int8_ref(
            q, k, v, ks, vs, valid_len, k_new=k_new, v_new=v_new,
            sm_scale=sm_scale, out_dtype=out_dtype)
        return out
    # query-group rows padded to the sublane floor of q's dtype (f32 8,
    # bf16 16) — the (1, 1, G, hd) query block must be a legal tile
    sub = 8 if q.dtype == jnp.float32 else 16
    gp = max(sub, -(-g // sub) * sub)
    qp = _pad_to(_pad_to(q, gp, 2), 128, 3)
    kp = _pad_to(_pad_to(k, blk_s, 1), 128, 3)
    vp = _pad_to(_pad_to(v, blk_s, 1), 128, 3)
    ksp = _pad_to(ks, blk_s, 1)
    vsp = _pad_to(vs, blk_s, 1)
    knp = _pad_to(k_new, 128, 3) if k_new is not None else None
    vnp = _pad_to(v_new, 128, 3) if v_new is not None else None
    from repro.kernels import decode_attention as _da
    out = _da.decode_attention_int8(
        qp, kp, ksp, vp, vsp, jnp.asarray(valid_len), knp, vnp,
        blk_s=blk_s, sm_scale=sm_scale, out_dtype=out_dtype,
        interpret=interpret and not _on_tpu())
    return out[:, :, :g, :hd]


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    blk_q: int = 128, blk_k: int = 128,
                    out_dtype=None, interpret: bool = False):
    """Fused flash attention.  q: (B, Sq, H, hd); k, v: (B, Skv, H, hd)
    (KV already expanded to H heads).  TPU -> Pallas kernel; CPU -> dense
    oracle unless ``interpret=True`` (kernel body under the interpreter).
    """
    from repro.kernels import flash_attention as _fa
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    out_dtype = out_dtype or q.dtype
    use_pallas = _on_tpu() or interpret
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    if use_pallas:
        bq = min(blk_q, max(8, sq))
        bk = min(blk_k, max(8, skv))
        qp = _pad_to(_pad_to(qr, bq, 1), 128, 2)
        kp = _pad_to(_pad_to(kr, bk, 1), 128, 2)
        vp = _pad_to(_pad_to(vr, bk, 1), 128, 2)
        out = _fa.flash_attention_bhsd(
            qp, kp, vp, blk_q=bq, blk_k=bk, causal=causal, window=window,
            kv_len=skv, sm_scale=hd ** -0.5, out_dtype=out_dtype,
            interpret=interpret and not _on_tpu())
        out = out[:, :sq, :hd]
    else:
        out = _ref.flash_attention_ref(qr, kr, vr, causal=causal,
                                       window=window, out_dtype=out_dtype)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
