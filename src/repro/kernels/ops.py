"""Public jit'd wrappers around the Pallas quantized-matmul kernels.

Responsibilities:
- accept ND activations (leading dims flattened to M),
- pad M/N/K up to MXU-aligned block multiples and slice the result back,
- dispatch: TPU backend -> compiled Pallas kernel; CPU -> the jnp oracle
  (numerically identical contract) unless ``interpret=True`` is forced, which
  runs the actual kernel body through the Pallas interpreter for validation,
- integrate with `repro.core.quant.QTensor`.

This is the only module model code should import from kernels/.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor, quantize
from repro.kernels import qmatmul as _k
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - defensive
        return False


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_block(size: int, pref: int, align: int) -> int:
    """Largest block <= pref that is a multiple of ``align`` covering size."""
    if size <= align:
        return align
    return min(pref, ((size + align - 1) // align) * align if size < pref else pref)


@functools.partial(jax.jit, static_argnames=(
    "activation", "out_dtype", "interpret", "bm", "bn", "bk"))
def qmatmul(x, w: QTensor, bias: Optional[jax.Array] = None, *,
            x_q: Optional[QTensor] = None, activation: str = "none",
            out_dtype=jnp.bfloat16, interpret: bool = False,
            bm: int = 128, bn: int = 128, bk: int = 256) -> jax.Array:
    """act((x @ dequant(w)) + bias) with int8 weights.

    ``x`` fp array of shape (..., K); ``w`` QTensor (K, N) with per-column
    scales.  If ``x_q`` is given (pre-quantized activations, per-tensor
    scale), the full w8a8 integer path runs; otherwise weight-only w8a16.
    """
    if not isinstance(w, QTensor):
        raise TypeError("w must be a QTensor; quantize with quantize_weight()")
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = w.shape[-1]
    x2 = x.reshape(-1, kdim)
    m = x2.shape[0]

    w_scale = w.scale.reshape(-1)
    use_pallas = _on_tpu() or interpret
    run_interp = interpret and not _on_tpu()

    if x_q is not None:
        xq2 = x_q.values.reshape(-1, kdim)
        xs = x_q.scale.reshape(())
        if use_pallas:
            xp = _pad_to(_pad_to(xq2, bm, 0), bk, 1)
            wp = _pad_to(_pad_to(w.values, bk, 0), bn, 1)
            wsp = _pad_to(w_scale, bn, 0)
            bp = _pad_to(bias, bn, 0) if bias is not None else None
            out = _k.qmatmul_w8a8(
                xp, wp, xs, wsp, bp, bm=bm, bn=bn, bk=bk,
                activation=activation, out_dtype=out_dtype,
                interpret=run_interp)
            return out[:m, :n].reshape(*lead, n)
        out = _ref.qmatmul_w8a8_ref(
            xq2, w.values, xs, w_scale, bias,
            activation=activation, out_dtype=out_dtype)
        return out.reshape(*lead, n)

    if use_pallas:
        xp = _pad_to(_pad_to(x2, bm, 0), bk, 1)
        wp = _pad_to(_pad_to(w.values, bk, 0), bn, 1)
        wsp = _pad_to(w_scale, bn, 0)
        bp = _pad_to(bias, bn, 0) if bias is not None else None
        out = _k.qmatmul_w8a16(
            xp, wp, wsp, bp, bm=bm, bn=bn, bk=bk,
            activation=activation, out_dtype=out_dtype,
            interpret=run_interp)
        return out[:m, :n].reshape(*lead, n)
    out = _ref.qmatmul_w8a16_ref(
        x2, w.values, w_scale, bias,
        activation=activation, out_dtype=out_dtype)
    return out.reshape(*lead, n)


def qmatmul_dynamic(x, w: QTensor, bias=None, *, activation: str = "none",
                    out_dtype=jnp.bfloat16, interpret: bool = False):
    """w8a8 with on-the-fly per-tensor activation quantization (the TPU's
    quantize-on-entry-to-UB behaviour)."""
    x_q = quantize(x.astype(jnp.float32), bits=8, axis=None)
    return qmatmul(x, w, bias, x_q=x_q, activation=activation,
                   out_dtype=out_dtype, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    blk_q: int = 128, blk_k: int = 128,
                    out_dtype=None, interpret: bool = False):
    """Fused flash attention.  q: (B, Sq, H, hd); k, v: (B, Skv, H, hd)
    (KV already expanded to H heads).  TPU -> Pallas kernel; CPU -> dense
    oracle unless ``interpret=True`` (kernel body under the interpreter).
    """
    from repro.kernels import flash_attention as _fa
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    out_dtype = out_dtype or q.dtype
    use_pallas = _on_tpu() or interpret
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, skv, hd)
    if use_pallas:
        bq = min(blk_q, max(8, sq))
        bk = min(blk_k, max(8, skv))
        qp = _pad_to(_pad_to(qr, bq, 1), 128, 2)
        kp = _pad_to(_pad_to(kr, bk, 1), 128, 2)
        vp = _pad_to(_pad_to(vr, bk, 1), 128, 2)
        out = _fa.flash_attention_bhsd(
            qp, kp, vp, blk_q=bq, blk_k=bk, causal=causal, window=window,
            kv_len=skv, sm_scale=hd ** -0.5, out_dtype=out_dtype,
            interpret=interpret and not _on_tpu())
        out = out[:, :sq, :hd]
    else:
        out = _ref.flash_attention_ref(qr, kr, vr, causal=causal,
                                       window=window, out_dtype=out_dtype)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
