"""Pure-jnp oracles for the Pallas kernels in this package.

Every kernel in kernels/ must agree with its oracle here (tests sweep shapes
and dtypes in interpret mode).  The oracles are also the CPU fallback used by
ops.py when not running on TPU and not asked for interpret-mode execution.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _activate(x: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(x)
    if activation == "silu":
        return x * jax.nn.sigmoid(x)
    if activation == "tanh":
        return jnp.tanh(x)
    if activation == "sigmoid":
        return jax.nn.sigmoid(x)
    raise ValueError(f"unknown activation {activation!r}")


def qmatmul_w8a8_ref(x: jax.Array, w: jax.Array, x_scale: jax.Array,
                     w_scale: jax.Array, bias: Optional[jax.Array] = None, *,
                     activation: str = "none",
                     out_dtype=jnp.float32) -> jax.Array:
    """int8 x int8 -> int32 -> dequant -> bias -> act, bit-exact accumulate."""
    acc = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * x_scale.astype(jnp.float32) \
        * w_scale.reshape(1, -1).astype(jnp.float32)
    if bias is not None:
        out = out + bias.reshape(1, -1).astype(jnp.float32)
    return _activate(out, activation).astype(out_dtype)


def qmatmul_w8a16_ref(x: jax.Array, w: jax.Array, w_scale: jax.Array,
                      bias: Optional[jax.Array] = None, *,
                      activation: str = "none",
                      out_dtype=jnp.bfloat16) -> jax.Array:
    """fp acts x dequantized int8 weights, fp32 accumulate."""
    w_fp = w.astype(jnp.float32) * w_scale.reshape(1, -1).astype(jnp.float32)
    acc = jax.lax.dot_general(
        x.astype(jnp.float32), w_fp,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.reshape(1, -1).astype(jnp.float32)
    return _activate(acc, activation).astype(out_dtype)


def decode_attention_int8_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                              k_scale: jax.Array, v_scale: jax.Array,
                              valid_len, *, k_new=None, v_new=None,
                              sm_scale=None,
                              out_dtype=jnp.float32) -> jax.Array:
    """Dense one-token attention against an int8 KV cache.

    q: (B, KV, G, hd) fp; k, v: (B, S, KV, hd) int8; k_scale, v_scale:
    (B, S, KV) or (B, S, KV, 1) fp32; valid_len: () or (B,) int32 — slots
    with index < valid_len[b] participate.  ``k_new``/``v_new``
    (B, 1, KV, hd) or (B, KV, hd) fp: the append path's current-token
    k/v, one extra (always-valid) softmax column.  Dequantizes the cache
    densely (the thing the fused kernel avoids) and runs a masked softmax.
    """
    b, _, _, hd = q.shape
    kvh = k.shape[2]
    sm_scale = hd ** -0.5 if sm_scale is None else sm_scale
    ks = k_scale.reshape(k.shape[:3]).astype(jnp.float32)
    vs = v_scale.reshape(v.shape[:3]).astype(jnp.float32)
    kf = k.astype(jnp.float32) * ks[..., None]
    vf = v.astype(jnp.float32) * vs[..., None]
    scores = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                        kf) * sm_scale
    vl = jnp.asarray(valid_len).reshape(-1, 1)          # (1|B, 1)
    valid = jnp.arange(k.shape[1])[None, :] < vl        # (1|B, S)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    if k_new is None:
        probs = jax.nn.softmax(scores, axis=-1)
        probs = jnp.where(valid[:, None, None, :], probs, 0.0)
        return jnp.einsum("bkgs,bskd->bkgd", probs, vf).astype(out_dtype)
    kn = k_new.reshape(b, kvh, hd).astype(jnp.float32)
    vn = v_new.reshape(b, kvh, hd).astype(jnp.float32)
    s_new = jnp.einsum("bkgd,bkd->bkg", q.astype(jnp.float32),
                       kn) * sm_scale
    scores = jnp.concatenate([scores, s_new[..., None]], axis=-1)
    probs = jax.nn.softmax(scores, axis=-1)             # (B, KV, G, S+1)
    p_cache, p_new = probs[..., :-1], probs[..., -1]
    p_cache = jnp.where(valid[:, None, None, :], p_cache, 0.0)
    out = jnp.einsum("bkgs,bskd->bkgd", p_cache, vf) \
        + p_new[..., None] * vn[:, :, None, :]
    return out.astype(out_dtype)


def decode_attention_paged_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                               k_scale: jax.Array, v_scale: jax.Array,
                               valid_len, block_tables: jax.Array, *,
                               k_new=None, v_new=None, sm_scale=None,
                               out_dtype=jnp.float32) -> jax.Array:
    """Oracle for the paged (block-table) decode-attention kernel.

    k, v: (NB, bs, KV, hd) int8 physical blocks; k_scale, v_scale:
    (NB, bs, KV) or (NB, bs, KV, 1) fp32; block_tables: (B, MB) int32 —
    row b's logical position p lives at block ``block_tables[b, p // bs]``
    offset ``p % bs``.  Gathers the blocks into the contiguous
    (B, MB*bs, ...) layout and delegates to the dense oracle, so the
    paged kernel's contract IS the dense kernel's contract composed with
    the table gather.
    """
    def gather(c):
        g = c[block_tables]                   # (B, MB, bs, ...)
        return g.reshape((g.shape[0], g.shape[1] * g.shape[2])
                         + g.shape[3:])

    ks = k_scale.reshape(k.shape[:3])
    vs = v_scale.reshape(v.shape[:3])
    return decode_attention_int8_ref(
        q, gather(k), gather(v), gather(ks), gather(vs), valid_len,
        k_new=k_new, v_new=v_new, sm_scale=sm_scale, out_dtype=out_dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window=None,
                        kv_len=None, out_dtype=jnp.bfloat16) -> jax.Array:
    """Dense softmax attention oracle.  q: (BH, Sq, hd); k,v: (BH, Skv, hd)."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    kv_len = skv if kv_len is None else kv_len
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid keys (can happen under padding) -> zero output
    p = jnp.where(mask[None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(out_dtype)
