"""Llama-3.2-Vision-90B [hf:meta-llama] — cross-attn image layers (stub)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    rope_theta=500000.0, activation="silu", gated_mlp=True,
    tie_embeddings=False, xattn_every=5, n_patches=1601,
    notes="100 decoder layers; gated cross-attention to stubbed vision "
          "patch embeddings every 5th layer (20 cross-attn layers).",
))
