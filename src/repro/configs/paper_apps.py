"""The paper's six production NN apps (Table 1) as buildable model configs.

These are the actual workload the TPU was evaluated on; `models/paper_nets.py`
builds runnable JAX versions whose weight counts match Table 1 (the
roofline-relevant quantity), and the serving example runs them through the
quantized path with the Table 4 batch scheduler.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class PaperAppConfig:
    name: str
    kind: str                 # "mlp" | "lstm" | "cnn"
    batch: int                # paper's TPU batch size (Table 1)
    deadline_ms: float        # response-time bound (7 ms for user-facing)
    # mlp: layer widths; lstm: (n_cells, width); cnn: conv spec
    widths: Tuple[int, ...] = ()
    n_cells: int = 0
    hidden: int = 0
    conv_channels: Tuple[int, ...] = ()
    spatial: int = 0          # input HxW
    fc_tail: Tuple[int, ...] = ()
    weights_target_m: float = 0.0   # Table 1 "Weights" column


PAPER_APP_CONFIGS = {
    # 5 FC layers, 20M weights, batch 200 (RankBrain-like)
    "MLP0": PaperAppConfig("MLP0", "mlp", batch=200, deadline_ms=7.0,
                           widths=(2000,) * 5, weights_target_m=20.0),
    # 4 FC layers, 5M weights, batch 168
    "MLP1": PaperAppConfig("MLP1", "mlp", batch=168, deadline_ms=7.0,
                           widths=(1118,) * 4, weights_target_m=5.0),
    # 52M weights across 24 gate matmuls -> 6 cells, width 1042
    "LSTM0": PaperAppConfig("LSTM0", "lstm", batch=64, deadline_ms=10.0,
                            n_cells=6, hidden=1042, weights_target_m=52.0),
    # 34M weights; the paper cites its 600-wide matrices
    "LSTM1": PaperAppConfig("LSTM1", "lstm", batch=96, deadline_ms=7.0,
                            n_cells=9, hidden=688, weights_target_m=34.0),
    # AlphaGo-style: 19x19 board, 16 conv layers of 256 3x3 filters ~ 8M
    "CNN0": PaperAppConfig("CNN0", "cnn", batch=8, deadline_ms=10.0,
                           conv_channels=(256,) * 16, spatial=19,
                           weights_target_m=8.0),
    # Inception-like: 72 conv (~28M) + 4 FC (~72M) = 100M
    "CNN1": PaperAppConfig("CNN1", "cnn", batch=32, deadline_ms=10.0,
                           conv_channels=(208,) * 72, spatial=28,
                           fc_tail=(3700, 7400, 3700, 1000),
                           weights_target_m=100.0),
}
