"""StarCoder2-3B [arXiv:2402.19173; hf] — dense, GQA kv=2, RoPE."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152, head_dim=128,
    rope_theta=999999.0, qkv_bias=True, activation="gelu", gated_mlp=False,
    norm="layernorm", tie_embeddings=True,
    notes="GQA kv=2, RoPE, non-gated GeLU MLP, LayerNorm (per paper).",
))
