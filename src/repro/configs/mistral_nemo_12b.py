"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense, 128k ctx."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1000000.0, activation="silu", gated_mlp=True,
    tie_embeddings=False,
    notes="GQA kv=8, SwiGLU, RMSNorm, 128k context (rope theta 1e6).",
))
