"""Qwen1.5-32B [hf:Qwen family] — dense, QKV bias, kv=40 (MHA)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064, head_dim=128,
    rope_theta=1000000.0, qkv_bias=True, activation="silu", gated_mlp=True,
    tie_embeddings=False,
    notes="Full MHA (kv=40), QKV bias per Qwen1.5.",
))
