"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60e top-4 + 4 shared."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, head_dim=128,
    activation="silu", gated_mlp=True, qkv_bias=True,
    n_experts=60, top_k=4, n_shared_experts=4,
    notes="60 routed experts top-4 plus 4 always-on shared experts; "
          "expert d_ff=1408.",
))
