"""Architecture configs: one module per assigned architecture + the six
paper apps.  ``get_config(name)`` is the registry entry point."""
from repro.configs.base import (ArchConfig, get_config, register,
                                list_archs, SHAPES, ShapeSpec)

# import for registration side effects
from repro.configs import (  # noqa: F401
    starcoder2_3b, mistral_nemo_12b, internlm2_20b, qwen1_5_32b,
    mamba2_1_3b, recurrentgemma_9b, qwen2_moe_a2_7b, mixtral_8x22b,
    whisper_medium, llama3_2_vision_90b, paper_apps)

__all__ = ["ArchConfig", "get_config", "register", "list_archs", "SHAPES",
           "ShapeSpec"]
