"""RecurrentGemma-9B [arXiv:2402.19427] — RG-LRU + local attention, 1:2."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    activation="gelu", gated_mlp=True,
    block_pattern=("rec", "rec", "attn"), local_window=2048, rnn_width=4096,
    subquadratic=True,
    notes="Griffin pattern: 2 RG-LRU recurrent blocks per local-attn block "
          "(window 2048, MQA kv=1); fixed-size state -> long_500k runnable.",
))
