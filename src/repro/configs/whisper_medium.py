"""Whisper-medium [arXiv:2212.04356] — enc-dec; conv frontend stubbed."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, head_dim=64,
    activation="gelu", gated_mlp=False, norm="layernorm",
    n_enc_layers=24, enc_seq=1500,
    notes="24 enc + 24 dec layers; conv frontend is a stub (input_specs "
          "provides precomputed frame embeddings). Decode shapes exercise "
          "the decoder with a 32k self-cache per the assignment shape "
          "(beyond Whisper's 448 but well-defined on the backbone).",
))
