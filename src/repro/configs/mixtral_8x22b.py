"""Mixtral-8x22B [arXiv:2401.04088] — 8 experts top-2, SWA per assignment."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    rope_theta=1000000.0, activation="silu", gated_mlp=True,
    n_experts=8, top_k=2, window=4096, tie_embeddings=False,
    subquadratic=True,
    notes="8 experts top-2; sliding-window attention (4096) per the "
          "assignment spec -> long_500k runnable (bounded KV).",
))
