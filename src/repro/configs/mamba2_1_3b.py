"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSM (SSD)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256, conv_width=4,
    subquadratic=True,
    notes="SSD (state-space duality): chunked intra/inter computation; "
          "attention-free -> long_500k runnable.",
))
