"""ArchConfig: the single config record every subsystem consumes.

Each assigned architecture file instantiates one ``ArchConfig`` with the
exact published dimensions and registers it.  ``reduced()`` derives the
small same-family variant used by CPU smoke tests.  ``input_specs`` /
``model_flops`` feed the dry-run and the roofline analysis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: Optional[int] = None     # sliding-window attention
    activation: str = "silu"
    gated_mlp: bool = True
    norm: str = "rmsnorm"
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid (recurrentgemma): block pattern unit, e.g. ("rec","rec","attn")
    block_pattern: Tuple[str, ...] = ()
    local_window: int = 2048
    rnn_width: int = 0
    # encdec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500              # stubbed audio-frame embeddings
    # vlm
    xattn_every: int = 0             # cross-attn every k-th layer
    n_patches: int = 1601            # stubbed vision-patch embeddings
    # serving options
    kv_quant: bool = False           # int8 KV cache (paper's 8-bit insight)
    # capability flags
    subquadratic: bool = False       # can run long_500k
    has_decode: bool = True
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))
        assert self.family in FAMILIES, self.family

    # ------------------------------------------------------------------
    # derived sizes
    # ------------------------------------------------------------------

    @property
    def d_inner(self) -> int:        # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Total parameters N (embedding included once when tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
        mlp_dense = d * f * (3 if self.gated_mlp else 2)
        per_layer: float
        if self.family == "ssm":
            din, n, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = (d * (2 * din + 2 * n + nh)   # in_proj (x,z,B,C,dt)
                         + self.conv_width * (din + 2 * n)
                         + din * d + 2 * nh + din)    # out_proj, A/dt_bias, D
        elif self.family == "moe":
            e_ff = d * f * (3 if self.gated_mlp else 2)
            per_layer = (attn + self.n_experts * e_ff
                         + self.n_shared_experts * e_ff + d * self.n_experts)
        elif self.family == "hybrid":
            pat = self.block_pattern or ("rec",)
            rnn = self.rnn_width or d
            rec = (2 * d * rnn + self.conv_width * rnn + rnn * d
                   + 2 * rnn) + mlp_dense
            att = attn + mlp_dense
            mix = sum(rec if b == "rec" else att for b in pat) / len(pat)
            per_layer = mix
        elif self.family == "encdec":
            # decoder layer: self-attn + cross-attn + mlp; encoder: attn+mlp
            enc = attn + mlp_dense
            dec = 2 * attn + mlp_dense
            return int(emb + self.n_enc_layers * enc + self.n_layers * dec
                       + (self.enc_seq + 4096) * d)  # pos embeds
        elif self.family == "vlm":
            n_x = self.n_layers // max(1, self.xattn_every)
            return int(emb + self.n_layers * (attn + mlp_dense)
                       + n_x * attn)
        else:
            per_layer = attn + mlp_dense
        return int(emb + self.n_layers * per_layer)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        e_ff = d * f * (3 if self.gated_mlp else 2)
        inactive = (self.n_experts - self.top_k) * e_ff
        return int(self.param_count() - self.n_layers * inactive)

    def model_flops(self, shape: ShapeSpec) -> float:
        """MODEL_FLOPS for the roofline's useful-compute ratio.

        train: 6 * N_active * tokens (fwd 2x + bwd 4x);
        prefill: 2 * N_active * tokens;
        decode: 2 * N_active * new tokens (= batch).
        Attention score/context flops excluded by convention (6ND).
        """
        n_act = self.active_param_count()
        if shape.kind == "train":
            return 6.0 * n_act * shape.seq_len * shape.global_batch
        if shape.kind == "prefill":
            return 2.0 * n_act * shape.seq_len * shape.global_batch
        return 2.0 * n_act * shape.global_batch

    # ------------------------------------------------------------------
    # dry-run inputs
    # ------------------------------------------------------------------

    def input_specs(self, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this cell.

        No device allocation; weak-type-correct; shardable along batch.
        """
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        else:  # decode: one new token against an S-long cache
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "cache_index": jax.ShapeDtypeStruct((), i32),
            }
        if self.family == "encdec":
            # stubbed conv-frontend output: precomputed frame embeddings
            specs["encoder_embeds"] = jax.ShapeDtypeStruct(
                (b, self.enc_seq, self.d_model), jnp.bfloat16)
        if self.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, self.n_patches, self.d_model), jnp.bfloat16)
        return specs

    def supports(self, shape: ShapeSpec) -> Tuple[bool, str]:
        """(runnable, reason-if-not) for an (arch x shape) cell."""
        if shape.kind == "decode" and not self.has_decode:
            return False, "encoder-only: no decode step"
        if shape.name == "long_500k" and not self.subquadratic:
            return False, "full attention is quadratic at 500k (DESIGN.md §6)"
        return True, ""

    # ------------------------------------------------------------------
    # smoke-test variant
    # ------------------------------------------------------------------

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        def shrink_heads(h):
            return max(1, min(h, 4))
        kv = max(1, min(self.n_kv_heads, 2))
        h = max(kv, shrink_heads(self.n_heads))
        h = (h // kv) * kv or kv
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 * max(1, len(self.block_pattern)
                                                or 1)),
            d_model=128, n_heads=h, n_kv_heads=kv,
            d_ff=256, vocab=512, head_dim=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # drop-free capacity so decode == teacher-forced forward in tests
            capacity_factor=2.0 if self.n_experts else self.capacity_factor,
            n_shared_experts=min(self.n_shared_experts, 1)
            if self.n_shared_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else self.ssm_headdim,
            ssm_chunk=16 if self.ssm_state else self.ssm_chunk,
            rnn_width=128 if self.rnn_width else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=16 if self.family == "encdec" else self.enc_seq,
            xattn_every=2 if self.xattn_every else 0,
            n_patches=8 if self.family == "vlm" else self.n_patches,
            local_window=32,
            window=min(self.window, 64) if self.window else None,
        )


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return sorted(_REGISTRY)
