"""Sharding rules: logical activation/param axes -> mesh axes.

Mesh axes (launch/mesh.py):
    single pod:  ("data", "model")           = (16, 16)   256 chips
    multi-pod:   ("pod", "data", "model")    = (2, 16, 16) 512 chips

Parallelism scheme (DESIGN.md §4):
- batch ("dp")    over ("pod", "data")  — pure DP across pods, so the only
  cross-pod collective is the gradient all-reduce (cheapest to overlap, and
  the one gradient compression applies to);
- FSDP ("fsdp")   over "data" — parameter/optimizer sharding within a pod;
- TP   ("tp")     over "model" — head/FFN sharding, all-reduce per block;
- SP   ("sp")     over "model" — sequence dim for long-context decode caches
  and (optional rule set) norm/elementwise sections.

Rules are data, not code: the §Perf hillclimb swaps rule sets without
touching model code.  ``constrain`` is a no-op unless a rule set is active,
so models run unsharded on CPU tests unchanged.
"""
from __future__ import annotations

import dataclasses
import re
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical -> physical mesh axis (resolved per-mesh; "dp" expands to the
# batch axes present in the mesh)
_LOGICAL = {"fsdp": "data", "tp": "model", "sp": "model"}


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Activation rules: logical name -> tuple of logical axes per dim.

    Entries use logical axis names: "dp", "fsdp", "tp", "sp" or None.
    """
    act: Tuple = ("dp", None, None)            # (B, S, D)
    act_heads: Tuple = ("dp", None, "tp", None)  # (B, S, H, hd)
    act_heads_decode: Tuple = ("dp", None, "tp", None)  # decode q (B,1,H,hd)
    act_ff: Tuple = ("dp", None, "tp")         # (B, S, F)
    kv_cache: Tuple = ("dp", "sp", None, None)  # (B, S_max, KV, hd)
    logits: Tuple = ("dp", None, "tp")         # (B, S, V)
    ssm_state: Tuple = ("dp", "tp", None, None)  # (B, H, hd, N)
    rnn_state: Tuple = ("dp", "tp")            # (B, D_rnn)
    conv_state: Tuple = ("dp", None, "tp")     # (B, width-1, C)
    moe_inter: Tuple = (None, "dp", None)      # (E, C, D) legacy dispatch
    moe_disp: Tuple = ("dp", None, None, None)  # (B, E, C_row, D) dispatch

    # param rules: regex over the param path -> per-dim logical axes.
    # Matched in order; first hit wins.  Leading L (scan) dim is implicit
    # (prepend None when the leaf has one more dim than the rule).
    params: Tuple = (
        (r"embed.*table", ("tp", "fsdp")),               # (V, D)
        (r"(wq|wk|wv|w_up|w_gate)\.w$", ("fsdp", "tp")),  # (D, F/Hhd)
        (r"(wo|w_down)\.w$", ("tp", "fsdp")),            # (F/Hhd, D)
        (r"(router|w_router)\.w$", (None, None)),        # tiny, replicated
        (r"experts.*(w_up|w_gate)", (None, "fsdp", "tp")),  # (E, D, F)
        (r"experts.*w_down", (None, "tp", "fsdp")),      # (E, F, D)
        (r"(wq|wk|wv|wo|w_up|w_gate|w_down)\.b$", ("tp",)),
        (r"conv.*\.w$", (None, None, "tp")),             # (width, 1, D)
        (r"(in_proj|x_proj|dt_proj)\.w$", ("fsdp", "tp")),
        (r"out_proj\.w$", ("tp", "fsdp")),
        (r"(a_log|dt_bias|D|Lambda|rg_.*)$", ("tp",)),   # per-channel ssm/rnn
        (r".*", ()),                                     # default: replicate
    )


BASELINE_RULES = ShardingRules()

# Sequence-parallel variant: shard the sequence dim of (B, S, D) activations
# over "model" in the elementwise/norm sections (perf-iteration candidate).
SEQPAR_RULES = dataclasses.replace(
    BASELINE_RULES, act=("dp", "sp", None))

# §Perf iteration A1 (refuted): decode KV cache sharded on head_dim over
# "model" instead of the sequence dim.
KVHD_RULES = dataclasses.replace(
    BASELINE_RULES, kv_cache=("dp", None, None, "tp"))

# §Perf iteration A2: keep the cache S-sharded, but leave the decode query
# REPLICATED over "model".  The measured collective term came from GSPMD
# resharding the (expanded, f32) cache to match the head-sharded q; with q
# replicated, scores are computed against the local S-shard and softmax /
# context need only tiny stat all-reduces.
DECODE_V2_RULES = dataclasses.replace(
    BASELINE_RULES, act_heads_decode=("dp", None, None, None))

# §Perf iteration B1: MoE dispatch buffer (E, C, D) sharded over experts.
MOE_EP_RULES = dataclasses.replace(
    DECODE_V2_RULES, moe_inter=("tp", "dp", None))

# §Perf iteration A5: batch-only cache sharding for small-KV (GQA) archs —
# the int8 cache of a kv<=8 model fits replicated over "model"
# (starcoder2 decode_32k: 30 GB / 16 data-rows = 1.9 GB/chip), making both
# the post-scan append and every attention read purely local.
DECODE_V3_RULES = dataclasses.replace(
    DECODE_V2_RULES, kv_cache=("dp", None, None, None))

RULE_SETS = {
    "baseline": BASELINE_RULES,
    "seqpar": SEQPAR_RULES,
    "kvhd": KVHD_RULES,
    "decode_v2": DECODE_V2_RULES,
    "decode_v3": DECODE_V3_RULES,
    "moe_ep": MOE_EP_RULES,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[ShardingRules] = None
        self.manual: frozenset = frozenset()


_CTX = _Ctx()


class manual_axes:
    """Declare mesh axes as manual (shard_map) for constrain().

    Newer JAX exposes the manual set on the abstract mesh; on 0.4.x there is
    no in-trace introspection, so the step wrapper declares it explicitly
    around the shard_map body.
    """

    def __init__(self, axes):
        self.axes = frozenset(axes)

    def __enter__(self):
        self._prev = _CTX.manual
        _CTX.manual = _CTX.manual | self.axes
        return self

    def __exit__(self, *exc):
        _CTX.manual = self._prev
        return False


class use_rules:
    """Context manager activating (mesh, rules) for constrain()/specs."""

    def __init__(self, mesh: Optional[Mesh], rules: ShardingRules):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        self._prev = (_CTX.mesh, _CTX.rules)
        _CTX.mesh, _CTX.rules = self.mesh, self.rules
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._prev
        return False


def _resolve(axes: Tuple, mesh: Mesh,
             shape: Optional[Tuple[int, ...]] = None) -> P:
    """Logical axes tuple -> PartitionSpec for this mesh.

    When ``shape`` is given, any dim not divisible by its mesh-axis extent
    falls back to replicated — jit *argument* shardings (unlike internal
    constraints) require exact divisibility (e.g. vocab 50280 on a 16-way
    axis, or the batch-1 long_500k cell).
    """
    out = []
    for i, a in enumerate(axes):
        phys = None
        if a == "dp":
            dp = _dp_axes(mesh)
            phys = dp if len(dp) > 1 else (dp[0] if dp else None)
        elif a is not None:
            cand = _LOGICAL[a]
            phys = cand if cand in mesh.axis_names else None
        if phys is not None and shape is not None and i < len(shape):
            extent = 1
            for ax in (phys if isinstance(phys, tuple) else (phys,)):
                extent *= mesh.shape[ax]
            if shape[i] % extent != 0:
                phys = None
        out.append(phys)
    return P(*out)


def _manual_axes() -> frozenset:
    """Axes currently under manual (shard_map) control in this trace:
    the explicitly declared set (manual_axes), plus whatever the abstract
    mesh reports on JAX versions that expose it."""
    traced = frozenset()
    try:
        amesh = jax.sharding.get_abstract_mesh()
        if amesh is not None and not amesh.empty:
            traced = frozenset(
                n for n, t in zip(amesh.axis_names, amesh.axis_types)
                if t == jax.sharding.AxisType.Manual)
    except Exception:
        pass
    return _CTX.manual | traced


def constrain(x: jax.Array, logical_name: str) -> jax.Array:
    """with_sharding_constraint by logical name; no-op without active rules.

    Axes that are Manual in the current trace (inside a partial-manual
    shard_map, e.g. the int8 cross-pod gradient exchange) are dropped from
    the spec — they are already fixed by the enclosing shard_map.
    """
    if _CTX.rules is None or _CTX.mesh is None:
        return x
    if _manual_axes():
        # Inside a partial-manual shard_map (int8 cross-pod gradient
        # exchange): rely on GSPMD propagation from the in/out shardings.
        # Mixing explicit constraints with partial-manual trips an XLA SPMD
        # partitioner CHECK in this XLA version (verified on CPU backend).
        return x
    axes = getattr(_CTX.rules, logical_name, None)
    if axes is None:
        return x
    axes = axes[:x.ndim] if len(axes) >= x.ndim else \
        tuple(axes) + (None,) * (x.ndim - len(axes))
    spec = _resolve(axes, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def spec_for(logical_name: str, ndim: int, mesh: Mesh,
             rules: ShardingRules, shape=None) -> P:
    axes = getattr(rules, logical_name)
    axes = tuple(axes)[:ndim] + (None,) * max(0, ndim - len(axes))
    return _resolve(axes, mesh, shape)


def param_spec(path: str, ndim: int, mesh: Mesh,
               rules: ShardingRules, shape=None) -> P:
    """PartitionSpec for a parameter leaf given its tree path string."""
    for pattern, axes in rules.params:
        if re.search(pattern, path):
            axes = tuple(axes)
            if len(axes) < ndim:   # leading scan (L) / group dims: replicate
                axes = (None,) * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[-ndim:]
            return _resolve(axes, mesh, shape)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):          # DictKey
            parts.append(str(p.key))
        elif hasattr(p, "name"):       # GetAttrKey (e.g. QTensor fields)
            parts.append(str(p.name))
        elif hasattr(p, "idx"):        # SequenceKey
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def tree_shardings(tree, mesh: Mesh, rules: ShardingRules):
    """NamedSharding pytree for a parameter / optimizer-state pytree.

    QTensor leaves: `.values` shards by the enclosing weight's rule;
    `.scale` is tiny and replicated.
    """
    def leaf_spec(path, leaf):
        ndim = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", None)
        ps = _path_str(path)
        if ps.endswith(".scale"):
            return NamedSharding(mesh, P())
        if ps.endswith(".values"):
            ps = ps[: -len(".values")]
        return NamedSharding(mesh, param_spec(ps, ndim, mesh, rules, shape))
    return jax.tree_util.tree_map_with_path(leaf_spec, tree)


# decode-cache leaves: regex on path -> logical activation rule,
# right-aligned over the trailing dims (leading scan/group dims replicate).
_CACHE_RULES = (
    (r"(^|\.)(k|v|lo_k|lo_v|xk|xv)$", "kv_cache"),
    (r"(k|v)_scale$", "kv_cache"),
    (r"rnn_h", "rnn_state"),
    (r"(^|\.)h$", "ssm_state"),
    (r"conv", "conv_state"),
    (r"enc_out|vision", "act"),
)


def cache_shardings(cache_tree, mesh: Mesh, rules: ShardingRules):
    def leaf_spec(path, leaf):
        ps = _path_str(path)
        ndim = getattr(leaf, "ndim", 0)
        shape = getattr(leaf, "shape", None)
        for pattern, logical in _CACHE_RULES:
            if re.search(pattern, ps):
                axes = tuple(getattr(rules, logical))
                if len(axes) < ndim:
                    axes = (None,) * (ndim - len(axes)) + axes
                else:
                    axes = axes[-ndim:] if ndim else ()
                return NamedSharding(mesh, _resolve(axes, mesh, shape))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


def batch_spec(mesh: Mesh, ndim: int = 2, shape=None) -> P:
    """(B, S, ...) input batch: batch over all dp axes (replicated when the
    batch dim is not divisible, e.g. the batch-1 long_500k cell)."""
    dp = _dp_axes(mesh)
    first = dp if len(dp) > 1 else (dp[0] if dp else None)
    if first is not None and shape:
        extent = 1
        for ax in (first if isinstance(first, tuple) else (first,)):
            extent *= mesh.shape[ax]
        if shape[0] % extent != 0:
            first = None
    return P(first, *([None] * (ndim - 1)))
